"""The async request front-end: ``submit`` one query, get a ``Future``.

``Frontend`` is the user-facing layer of the serving tier.  It owns

* a registry of **compiled paths** (``register(spec_key, spec)`` ->
  ``Engine.compile``),
* a ``CoalescingBatcher`` grouping in-flight queries by
  ``(spec_key, hypergraph)``,
* one **worker thread** that continuously drains due batches into
  ``CompiledAlgorithm.run_batch`` and fans the rows back out to
  per-request futures,
* ``ServeMetrics`` for the wait/execute latency split, bucket
  occupancy and flush accounting (``stats()``).

Correctness contract: a request's resolved value is **bitwise identical
to a sequential ``CompiledAlgorithm.run(query=...)``** of the same query
— coalescing, batch padding and fan-out never touch the numbers
(``run_batch``'s own bitwise-vs-sequential guarantee carries through
row slicing).  Asserted by ``tests/test_serve.py`` on the local and
sharded backends.

Determinism for tests: the batcher is pure and the clock injectable;
an unstarted front-end can be driven synchronously with ``pump()``
(no thread, no sleeps), which the jit-free property tests use.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

import numpy as np

from repro.faults.errors import (
    CircuitOpen,
    DeadlineExceeded,
    FrontendClosed,
    PoisonQuery,
    is_transient,
)
from repro.obs.trace import maybe_span
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import AdaptiveDelay, CoalescingBatcher, Flush

DEFAULT_MAX_BATCH = 32
DEFAULT_MAX_DELAY_MS = 5.0
# Worker-crash requeues per request before the supervisor gives up and
# resolves the future with the crash: bounds the restart loop under a
# deterministic (always-firing) worker fault.
MAX_REQUEUES = 3


class _Breaker:
    """Per-group circuit breaker.

    ``threshold`` consecutive flush failures open the circuit; while
    open, flushes fast-fail with ``CircuitOpen`` (no execute attempt —
    a hard-down path stops burning retries and batch executes).  After
    ``cooldown_s`` one probe batch is allowed through (half-open):
    success closes the circuit, failure re-opens it for another
    cooldown.  Touched only by the flush-executing thread (worker or
    ``pump`` caller), so no lock is needed.
    """

    __slots__ = ("threshold", "cooldown_s", "failures", "opened_at")

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self.failures = 0
        self.opened_at: float | None = None

    def allow(self, now: float) -> bool:
        if self.opened_at is None:
            return True
        return now - self.opened_at >= self.cooldown_s  # half-open probe

    def record_failure(self, now: float) -> bool:
        """Fold in one flush failure; True when this one trips it open."""
        self.failures += 1
        if self.opened_at is not None:   # failed half-open probe:
            self.opened_at = now         # restart the cooldown
            return False
        if self.failures >= self.threshold:
            self.opened_at = now
            return True
        return False

    def record_success(self) -> None:
        self.failures = 0
        self.opened_at = None


@dataclasses.dataclass
class ServedResult:
    """What a request's ``Future`` resolves to.

    ``value`` is the spec's extracted output for THIS query (leading
    batch axis already sliced off, leaves as numpy arrays).  The rest is
    per-request observability: how long the query waited for
    co-batchable traffic, how long its batch executed, why and how full
    the batch flushed.
    """

    value: Any
    queue_wait_s: float
    execute_s: float
    flush_reason: str
    batch_size: int
    batch_bucket: int
    group: Any
    supersteps_executed: int | None = None


class _Path:
    """One registered compiled algorithm (a ``spec_key``)."""

    __slots__ = ("key", "compiled", "max_batch")

    def __init__(self, key, compiled, max_batch):
        self.key = key
        self.compiled = compiled
        self.max_batch = max_batch


class Frontend:
    """Coalescing request front-end over one ``Engine``.

    >>> fe = Frontend(engine, max_batch=32, max_delay_ms=5)
    >>> fe.register("sssp", shortest_paths_spec(hg, 0, 32))
    >>> fe.register("ppr", random_walk_spec(hg, iters=20))
    >>> with fe:                      # starts the worker thread
    ...     futs = [fe.submit("sssp", query=s) for s in sources]
    ...     results = [f.result() for f in futs]
    >>> fe.stats()                    # latency split, occupancy, caches

    ``max_batch`` should be the batch bucket the executables were
    warmed at (a power of two): a full flush then runs at occupancy 1.0
    while partial (deadline) flushes pad up to the same bucket set.
    """

    def __init__(
        self,
        engine,
        *,
        max_batch: int = DEFAULT_MAX_BATCH,
        max_delay_ms: float = DEFAULT_MAX_DELAY_MS,
        log_every_s: float | None = None,
        clock=time.monotonic,
        adaptive_delay: bool = False,
        min_delay_ms: float = 0.5,
        resilience: bool = True,
        max_retries: int = 2,
        retry_backoff_ms: float = 10.0,
        breaker_threshold: int = 5,
        breaker_cooldown_ms: float = 1000.0,
        fault_injector=None,
    ):
        self.engine = engine
        # Fault-tolerance knobs.  ``resilience=False`` is the bench
        # escape hatch: no retries, no bisect, no breaker, no deadline
        # checks — used to measure the fault-free overhead of the
        # resilient default (<2% asserted by bench_serve_tier).
        self._resilience = bool(resilience)
        self._injector = (
            fault_injector if fault_injector is not None
            else getattr(engine, "fault_injector", None)
        )
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_ms) / 1e3
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_s = float(breaker_cooldown_ms) / 1e3
        self._breakers: dict[Any, _Breaker] = {}
        self._sleep = time.sleep   # injectable: tests retry without waiting
        self._inflight: Flush | None = None
        self._worker_restarts = 0
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_ms) / 1e3
        self.clock = clock
        self.metrics = ServeMetrics(log_every_s=log_every_s)
        # Off by default: max_delay_ms stays a fixed deadline.  Opted
        # in, it becomes the UPPER bound of an AdaptiveDelay controller
        # fed by the observed flush reason / occupancy / execute time.
        self._adaptive = (
            AdaptiveDelay(
                self.max_delay_s,
                lo_s=float(min_delay_ms) / 1e3,
                hi_s=max(self.max_delay_s, float(min_delay_ms) / 1e3),
            )
            if adaptive_delay
            else None
        )
        self._paths: dict[Any, _Path] = {}
        self._batcher = CoalescingBatcher(
            capacity=lambda group: self._paths[group[0]].max_batch
        )
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        self._stop = False
        self._closed = False

    # -- registration ------------------------------------------------------

    def register(
        self, spec_key: Any, spec, *, max_batch: int | None = None,
        **overrides,
    ):
        """Register a servable path: an ``AlgorithmSpec`` (compiled via
        ``engine.compile(spec, **overrides)``) or anything already
        exposing ``run_batch`` (a ``CompiledAlgorithm``, or a test
        double).  Returns the compiled handle."""
        if hasattr(spec, "run_batch"):
            compiled = spec
        else:
            if getattr(spec, "bind_query", None) is None:
                raise ValueError(
                    f"spec {getattr(spec, 'name', spec)!r} has no "
                    "bind_query: the front-end batches per-request "
                    "queries; declare the query axis"
                )
            compiled = self.engine.compile(spec, **overrides)
        with self._lock:
            if self._closed:
                raise FrontendClosed("front-end is closed")
            if spec_key in self._paths:
                raise ValueError(f"spec_key {spec_key!r} already registered")
            self._paths[spec_key] = _Path(
                spec_key, compiled, int(max_batch or self.max_batch)
            )
        return compiled

    def compiled(self, spec_key: Any):
        return self._paths[spec_key].compiled

    # -- submission --------------------------------------------------------

    def submit(
        self,
        spec_key: Any,
        hg=None,
        query: Any = None,
        deadline_ms: float | None = None,
        timeout_ms: float | None = None,
    ) -> Future:
        """Enqueue one query; resolves to a ``ServedResult``.

        ``hg``: serve against this (same-shape-bucket) hypergraph
        instead of the spec's own; queries only coalesce within one
        hypergraph.  ``deadline_ms`` bounds this request's queue wait —
        when it expires the batch flushes with whatever co-arrived
        (default: the front-end's ``max_delay_ms``).  ``timeout_ms`` is
        the request's HARD deadline: a request the tier cannot dispatch
        by then (overload, retries, open circuit) resolves with
        ``DeadlineExceeded`` instead of hanging.  Raises
        ``FrontendClosed`` after ``close()``."""
        if spec_key not in self._paths:
            raise KeyError(
                f"unknown spec_key {spec_key!r}; register() it first"
            )
        if deadline_ms is not None:
            deadline_s = deadline_ms / 1e3
        elif self._adaptive is not None:
            deadline_s = self._adaptive.delay_s
        else:
            deadline_s = self.max_delay_s
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise FrontendClosed("front-end is closed")
            self._batcher.submit(
                (spec_key, id(hg) if hg is not None else 0),
                query,
                now=self.clock(),
                deadline_s=deadline_s,
                hg=hg,
                future=fut,
                expiry=(
                    self.clock() + timeout_ms / 1e3
                    if timeout_ms is not None else None
                ),
            )
            self._cond.notify()
        self.metrics.note_submit()
        return fut

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "Frontend":
        if self._thread is None:
            self._stop = False
            self._thread = threading.Thread(
                target=self._worker, name="repro-serve-frontend",
                daemon=True,
            )
            self._thread.start()
        return self

    def close(self) -> None:
        """Stop accepting and stop the worker; requests still queued at
        that point resolve exceptionally with ``FrontendClosed``.

        A closed front-end never leaves a caller hanging on a future —
        and never silently executes work after the owner said stop
        (callers that want a synchronous final drain call
        ``pump(drain=True)`` BEFORE closing)."""
        with self._cond:
            self._closed = True
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            flushes = self._batcher.drain()
        n = 0
        err = FrontendClosed(
            "front-end closed with this request still queued"
        )
        for flush in flushes:
            for r in flush.requests:
                if r.future is not None and not r.future.done():
                    r.future.set_exception(err)
                    n += 1
        if n:
            self.metrics.note_error(n)
            self.metrics.registry.counter("faults.serve.closed_failed").inc(n)

    def __enter__(self) -> "Frontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- execution ---------------------------------------------------------

    def pump(self, *, drain: bool = False) -> int:
        """Synchronously execute every due flush on the caller's thread.

        The single-threaded serving mode: property tests (fake clock,
        no sleeps) and simple replay loops call ``pump`` instead of
        ``start``.  ``drain=True`` also flushes not-yet-due groups."""
        n = 0
        while True:
            with self._lock:
                flush = self._batcher.poll(self.clock())
                due = (
                    [flush] if flush is not None
                    else self._batcher.drain() if drain
                    else []
                )
            if not due:
                return n
            for f in due:
                self._run_flush(f)
                n += 1

    def _worker(self) -> None:
        # Supervisor loop: ``_serve_loop`` IS the worker; a crash
        # anywhere in its flush path (including an injected
        # ``serve.worker`` fault) lands here, where the in-flight batch
        # is requeued (unresolved futures only, bounded by
        # ``MAX_REQUEUES``) and the loop restarts — one poisoned control
        # path cannot take the serving tier down with it.
        while True:
            try:
                self._serve_loop()
                return
            except Exception as err:  # noqa: BLE001 - supervised restart
                self._worker_restarts += 1
                self.metrics.registry.counter(
                    "faults.serve.worker_restarts"
                ).inc()
                flush, self._inflight = self._inflight, None
                if flush is not None:
                    self._requeue_after_crash(flush, err)

    def _requeue_after_crash(self, flush: Flush, err: Exception) -> None:
        survivors = []
        for r in flush.requests:
            if r.future is not None and r.future.done():
                continue
            r.requeues += 1
            if r.requeues > MAX_REQUEUES:
                # A request that keeps killing the worker resolves with
                # the crash itself — never silently dropped, never an
                # unbounded restart loop.
                self._fail(r, err)
                self.metrics.note_error()
            else:
                survivors.append(r)
        if survivors:
            with self._cond:
                self._batcher.requeue(Flush(
                    group=flush.group, requests=survivors,
                    reason=flush.reason, hg=flush.hg,
                ))
                self.metrics.registry.counter(
                    "faults.serve.requeued"
                ).inc(len(survivors))
                self._cond.notify_all()

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                flush = None
                while not self._stop:
                    flush = self._batcher.poll(self.clock())
                    if flush is not None:
                        break
                    horizon = self._batcher.next_deadline()
                    self._cond.wait(
                        timeout=None
                        if horizon is None
                        else max(horizon - self.clock(), 0.0)
                    )
                if flush is None and self._stop:
                    # close() resolves whatever is still queued with
                    # FrontendClosed; the worker just stops.
                    return
            self._inflight = flush
            if self._injector is not None:
                self._injector.maybe_raise(
                    "serve.worker", group=str(flush.group[0])
                )
            self._run_flush(flush)
            self._inflight = None
            self.metrics.maybe_log(self.clock())

    @staticmethod
    def _fail(req, err: Exception) -> None:
        if req.future is not None and not req.future.done():
            req.future.set_exception(err)

    def _run_flush(self, flush: Flush) -> None:
        path = self._paths[flush.group[0]]
        # Skip futures a crashed-and-requeued flush already resolved.
        reqs = [
            r for r in flush.requests
            if r.future is None or not r.future.done()
        ]
        if self._resilience and reqs:
            # Hard per-request deadline: a request the tier could not
            # dispatch in time resolves exceptionally, never hangs.
            now = self.clock()
            live = []
            expired = 0
            for r in reqs:
                if r.expiry is not None and now > r.expiry:
                    self._fail(r, DeadlineExceeded(
                        f"request for {flush.group[0]!r} expired "
                        f"{(now - r.expiry) * 1e3:.1f}ms past its deadline"
                    ))
                    expired += 1
                else:
                    live.append(r)
            if expired:
                self.metrics.note_error(expired)
                self.metrics.registry.counter(
                    "faults.serve.deadline_exceeded"
                ).inc(expired)
            reqs = live
            if reqs:
                breaker = self._breakers.get(flush.group)
                if breaker is not None and not breaker.allow(self.clock()):
                    err = CircuitOpen(
                        f"circuit open for group {flush.group[0]!r} "
                        f"after {breaker.failures} consecutive failures"
                    )
                    for r in reqs:
                        self._fail(r, err)
                    self.metrics.note_error(len(reqs))
                    self.metrics.registry.counter(
                        "faults.serve.breaker_fastfails"
                    ).inc(len(reqs))
                    return
        if reqs:
            self._execute_requests(path, flush, reqs, depth=0)

    def _execute_requests(
        self, path: _Path, flush: Flush, reqs: list, depth: int
    ) -> None:
        """Execute one (sub-)batch; on failure, bisect to isolate the
        poison request instead of failing every co-batched neighbor."""
        from repro.core.serving import BATCH_FLOOR, bucket_dim

        b = len(reqs)
        bucket = bucket_dim(b, floor=BATCH_FLOOR)
        dispatch = self.clock()
        waits = [dispatch - r.arrival for r in reqs]
        try:
            res, value, execute_s = self._attempt(
                path, flush, reqs, b, bucket, waits
            )
        except Exception as err:  # noqa: BLE001 - isolated or fanned out
            if self._resilience and b > 1:
                # Batch bisect: halve and retry each side independently;
                # only the poison request(s) ultimately fail, everyone
                # else is served.  log2(b) extra executes, worst case.
                self.metrics.registry.counter("faults.serve.bisects").inc()
                mid = b // 2
                self._execute_requests(path, flush, reqs[:mid], depth + 1)
                self._execute_requests(path, flush, reqs[mid:], depth + 1)
                return
            self._record_outcome(flush.group, ok=False)
            self.metrics.note_flush(
                flush.group[0], flush.reason, b, bucket, waits,
                self.clock() - dispatch, error=True,
            )
            if depth and self._resilience:
                wrapped = PoisonQuery(
                    f"query poisoned its batch "
                    f"(group {flush.group[0]!r}): {err}"
                )
                wrapped.__cause__ = err
                err = wrapped
            for r in reqs:
                self._fail(r, err)
            return
        self._record_outcome(flush.group, ok=True)
        executed = getattr(res, "supersteps_executed", None)
        # analysis: ignore[host-sync] — one scalar readback per FLUSH
        # (not per request) feeding the occupancy metrics
        executed = int(np.asarray(executed)) if executed is not None else None
        self.metrics.note_flush(
            flush.group[0], flush.reason, b, bucket, waits, execute_s,
        )
        if self._adaptive is not None:
            # Error flushes (above) don't feed the controller: their
            # execute time measures the failure, not the batch.
            self._adaptive.observe(
                execute_s=execute_s,
                occupancy=b / max(path.max_batch, 1),
                reason=flush.reason,
            )
        rows = _unstack(value, b)
        for i, r in enumerate(reqs):
            if r.future is None:
                continue
            r.future.set_result(ServedResult(
                value=rows[i],
                queue_wait_s=waits[i],
                execute_s=execute_s,
                flush_reason=flush.reason,
                batch_size=b,
                batch_bucket=bucket,
                group=flush.group[0],
                supersteps_executed=executed,
            ))

    def _attempt(self, path, flush, reqs, b, bucket, waits):
        """One execute with transient-failure retries (exponential
        backoff via the injectable ``self._sleep``)."""
        tracer = getattr(self.engine, "tracer", None)
        queries = _stack([r.query for r in reqs])
        attempt = 0
        while True:
            dispatch = self.clock()
            try:
                with maybe_span(
                    tracer, "serve.flush", cat="serve",
                    group=str(flush.group[0]), reason=flush.reason,
                    batch=b, bucket=bucket, attempt=attempt,
                ) as sp:
                    if self._injector is not None:
                        self._injector.maybe_raise(
                            "serve.flush", group=str(flush.group[0]),
                            batch=b,
                        )
                    res = path.compiled.run_batch(queries, hg=flush.hg)
                    value = res.value
                    if sp is not None:
                        tracer.block(sp, value)
                        sp.args["max_wait_s"] = max(waits, default=0.0)
                    else:
                        _block(value)
                return res, value, self.clock() - dispatch
            except Exception as err:
                if (
                    not self._resilience
                    or attempt >= self.max_retries
                    or not is_transient(err)
                ):
                    raise
                attempt += 1
                self.metrics.registry.counter("faults.serve.retries").inc()
                self._sleep(self.retry_backoff_s * (2 ** (attempt - 1)))

    def _record_outcome(self, group, *, ok: bool) -> None:
        if not self._resilience:
            return
        if ok:
            breaker = self._breakers.get(group)
            if breaker is not None:
                breaker.record_success()
            return
        breaker = self._breakers.setdefault(
            group, _Breaker(self.breaker_threshold, self.breaker_cooldown_s)
        )
        if breaker.record_failure(self.clock()):
            self.metrics.registry.counter(
                "faults.serve.breaker_trips"
            ).inc()

    # -- observability -----------------------------------------------------

    @property
    def current_delay_ms(self) -> float:
        """The flush deadline new submits get (adaptive or fixed)."""
        delay_s = (
            self._adaptive.delay_s if self._adaptive is not None
            else self.max_delay_s
        )
        return delay_s * 1e3

    def stats(self) -> dict:
        """One snapshot across all three layers: front-end latency /
        occupancy, the Engine's executable cache, the disk store — plus
        the unified metrics registry (every provider in one view)."""
        snap = self.metrics.snapshot()
        engine_stats = None
        if hasattr(self.engine, "cache_stats"):
            engine_stats = self.engine.cache_stats()
        snap["engine_cache"] = engine_stats
        disk = getattr(self.engine, "disk_cache", None)
        snap["disk_cache"] = disk.stats() if disk is not None else None
        snap["adaptive_delay"] = (
            self._adaptive.snapshot() if self._adaptive is not None else None
        )
        snap["registry"] = self.metrics.registry.snapshot()
        return snap


# -- pytree batch helpers (no jax import needed for the pure tests) --------

def _stack(queries: list[Any]):
    """Stack B query pytrees into one batched pytree (leading axis B)."""
    import jax

    return jax.tree.map(
        # analysis: ignore[host-sync] — batching host-side queries is the
        # ingest contract (rows are request-sized, not graph-sized)
        lambda *leaves: np.stack([np.asarray(x) for x in leaves]),
        *queries,
    )


def _unstack(value: Any, b: int) -> list[Any]:
    """Split a batched result pytree into B per-request pytrees."""
    import jax

    leaves, treedef = jax.tree.flatten(value)
    # analysis: ignore[host-sync] — fan-out materializes results the
    # futures are about to hand back; the one sync serving requires
    leaves = [np.asarray(leaf) for leaf in leaves]
    return [
        jax.tree.unflatten(treedef, [leaf[i] for leaf in leaves])
        for i in range(b)
    ]


def _block(value: Any) -> None:
    try:
        import jax

        # analysis: ignore[host-sync] — futures resolve to READY values
        # by contract (the tracer path measures this same wait)
        jax.block_until_ready(value)
    # analysis: ignore[swallowed-error] — numpy-only test doubles have
    # nothing to block on; readiness here is best-effort and the result
    # value is handed to the future either way
    except Exception:
        pass
