"""Latency observability for the serving tier.

Log-spaced histograms (p50/p99/p999 without storing samples) split into
the two halves a serving operator actually tunes against:

* **queue wait** — admission to dispatch: the price of coalescing.
  Grows with ``max_delay_ms`` and shrinks with traffic (fuller buckets
  flush sooner).
* **execute** — dispatch to results-ready: the price of the compiled
  batch itself.  Flat per bucket on the warm path; a spike here means a
  retrace / cache miss.

Plus per-bucket occupancy (how full each flushed batch bucket ran —
low occupancy = paying padded execution for empty slots), flush-reason
counters, and the engine/disk cache counters merged into one
``snapshot()``.  ``maybe_log`` emits a one-line summary at a bounded
rate for long-running serve loops.
"""
from __future__ import annotations

import bisect
import logging
import math
import threading
from collections import Counter
from typing import Any

log = logging.getLogger("repro.serve")

# Histogram bin upper bounds: 1us .. ~4600s, quarter-decade spacing —
# ~2x resolution per bin, 40 bins, fixed memory.
_BOUNDS = [1e-6 * (10 ** (i / 4)) for i in range(40)]


class LatencyHistogram:
    """Fixed-bin log histogram over seconds; quantiles report the upper
    bound of the covering bin (<= ~78% relative overestimate at
    quarter-decade spacing — plenty for p50-vs-p999 shape)."""

    def __init__(self):
        self._counts = [0] * (len(_BOUNDS) + 1)
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, seconds: float) -> None:
        seconds = max(float(seconds), 0.0)
        self._counts[bisect.bisect_left(_BOUNDS, seconds)] += 1
        self.count += 1
        self.total += seconds
        self.max = max(self.max, seconds)

    def quantile(self, q: float) -> float:
        """Upper bound of the bin holding the q-quantile (0 when empty)."""
        if self.count == 0:
            return 0.0
        target = math.ceil(q * self.count)
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target:
                return _BOUNDS[i] if i < len(_BOUNDS) else self.max
        return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.total / self.count if self.count else 0.0,
            "p50_s": self.quantile(0.50),
            "p99_s": self.quantile(0.99),
            "p999_s": self.quantile(0.999),
            "max_s": self.max,
        }


class ServeMetrics:
    """The front-end's counters; thread-safe (worker + submitters)."""

    def __init__(self, log_every_s: float | None = None):
        self._lock = threading.Lock()
        self.wait = LatencyHistogram()
        self.execute = LatencyHistogram()
        self.total = LatencyHistogram()
        self.flush_reasons: Counter = Counter()
        # (group key, batch bucket) -> occupancy accounting
        self.buckets: dict[Any, dict] = {}
        self.submitted = 0
        self.completed = 0
        self.errors = 0
        self.log_every_s = log_every_s
        self._last_log = None

    def note_submit(self, n: int = 1) -> None:
        with self._lock:
            self.submitted += n

    def note_flush(
        self,
        group: Any,
        reason: str,
        batch: int,
        bucket: int,
        wait_s: list[float],
        execute_s: float,
        error: bool = False,
    ) -> None:
        """One executed batch: per-request waits, one execute span."""
        with self._lock:
            self.flush_reasons[reason] += 1
            b = self.buckets.setdefault(
                (group, bucket),
                {"flushes": 0, "requests": 0, "occupancy_sum": 0.0},
            )
            b["flushes"] += 1
            b["requests"] += batch
            b["occupancy_sum"] += batch / bucket
            per_req_exec = execute_s
            for w in wait_s:
                self.wait.record(w)
                self.execute.record(per_req_exec)
                self.total.record(w + per_req_exec)
            if error:
                self.errors += batch
            else:
                self.completed += batch

    def snapshot(self) -> dict:
        with self._lock:
            buckets = {
                f"{group}/b{bucket}": {
                    **stats,
                    "mean_occupancy": (
                        stats["occupancy_sum"] / stats["flushes"]
                        if stats["flushes"]
                        else 0.0
                    ),
                }
                for (group, bucket), stats in sorted(
                    self.buckets.items(), key=lambda kv: repr(kv[0])
                )
            }
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "errors": self.errors,
                "in_flight": self.submitted - self.completed - self.errors,
                "queue_wait": self.wait.snapshot(),
                "execute": self.execute.snapshot(),
                "total_latency": self.total.snapshot(),
                "flush_reasons": dict(self.flush_reasons),
                "buckets": buckets,
            }

    def maybe_log(self, now: float) -> str | None:
        """Emit (and return) the periodic one-line summary when
        ``log_every_s`` has elapsed; None otherwise."""
        if self.log_every_s is None:
            return None
        with self._lock:
            if (
                self._last_log is not None
                and now - self._last_log < self.log_every_s
            ):
                return None
            self._last_log = now
        snap = self.snapshot()
        line = (
            f"serve: {snap['completed']} done / {snap['in_flight']} "
            f"in-flight | wait p50={snap['queue_wait']['p50_s'] * 1e3:.2f}ms "
            f"p99={snap['queue_wait']['p99_s'] * 1e3:.2f}ms | exec "
            f"p50={snap['execute']['p50_s'] * 1e3:.2f}ms "
            f"p99={snap['execute']['p99_s'] * 1e3:.2f}ms | flushes "
            f"{dict(snap['flush_reasons'])}"
        )
        log.info(line)
        return line
