"""Latency observability for the serving tier.

Log-spaced histograms (p50/p99/p999 without storing samples) split into
the two halves a serving operator actually tunes against:

* **queue wait** — admission to dispatch: the price of coalescing.
  Grows with ``max_delay_ms`` and shrinks with traffic (fuller buckets
  flush sooner).
* **execute** — dispatch to results-ready: the price of the compiled
  batch itself.  Flat per bucket on the warm path; a spike here means a
  retrace / cache miss.

Plus per-bucket occupancy (how full each flushed batch bucket ran —
low occupancy = paying padded execution for empty slots), flush-reason
counters, and the engine/disk cache counters merged into one
``snapshot()``.  ``maybe_log`` emits a one-line summary at a bounded
rate for long-running serve loops.

``LatencyHistogram`` moved to ``repro.obs.metrics`` (one histogram
implementation for the serving tier and the unified registry); it is
re-exported here for compatibility.  Every ``ServeMetrics`` also
registers itself as a ``serve.frontend`` snapshot provider on the
default ``MetricsRegistry``.
"""
from __future__ import annotations

import logging
import threading
from collections import Counter
from typing import Any

from repro.obs.metrics import (  # noqa: F401 - _BOUNDS re-exported for compat
    _BOUNDS,
    LatencyHistogram,
    default_registry,
    weak_provider,
)

log = logging.getLogger("repro.serve")


class ServeMetrics:
    """The front-end's counters; thread-safe (worker + submitters)."""

    def __init__(self, log_every_s: float | None = None, registry=None):
        self._lock = threading.Lock()
        self.wait = LatencyHistogram()
        self.execute = LatencyHistogram()
        self.total = LatencyHistogram()
        self.flush_reasons: Counter = Counter()
        # (group key, batch bucket) -> occupancy accounting
        self.buckets: dict[Any, dict] = {}
        self.submitted = 0
        self.completed = 0
        self.errors = 0
        self.log_every_s = log_every_s
        self._last_log = None
        self.registry = registry if registry is not None else (
            default_registry()
        )
        self._provider_name = self.registry.register_provider(
            "serve.frontend", weak_provider(self.snapshot)
        )

    def note_submit(self, n: int = 1) -> None:
        with self._lock:
            self.submitted += n

    def note_error(self, n: int = 1) -> None:
        """Requests resolved exceptionally OUTSIDE an executed flush
        (deadline-expired, circuit-open fast-fail, front-end closed) —
        keeps the ``in_flight`` balance exact."""
        with self._lock:
            self.errors += n

    def note_flush(
        self,
        group: Any,
        reason: str,
        batch: int,
        bucket: int,
        wait_s: list[float],
        execute_s: float,
        error: bool = False,
    ) -> None:
        """One executed batch: per-request waits, one execute span."""
        with self._lock:
            self.flush_reasons[reason] += 1
            b = self.buckets.setdefault(
                (group, bucket),
                {"flushes": 0, "requests": 0, "occupancy_sum": 0.0},
            )
            b["flushes"] += 1
            b["requests"] += batch
            b["occupancy_sum"] += batch / bucket
            per_req_exec = execute_s
            for w in wait_s:
                self.wait.record(w)
                self.execute.record(per_req_exec)
                self.total.record(w + per_req_exec)
            if error:
                self.errors += batch
            else:
                self.completed += batch

    def snapshot(self) -> dict:
        with self._lock:
            buckets = {
                f"{group}/b{bucket}": {
                    **stats,
                    "mean_occupancy": (
                        stats["occupancy_sum"] / stats["flushes"]
                        if stats["flushes"]
                        else 0.0
                    ),
                }
                for (group, bucket), stats in sorted(
                    self.buckets.items(), key=lambda kv: repr(kv[0])
                )
            }
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "errors": self.errors,
                "in_flight": self.submitted - self.completed - self.errors,
                "queue_wait": self.wait.snapshot(),
                "execute": self.execute.snapshot(),
                "total_latency": self.total.snapshot(),
                "flush_reasons": dict(self.flush_reasons),
                "buckets": buckets,
            }

    def maybe_log(self, now: float) -> str | None:
        """Emit (and return) the periodic one-line summary when
        ``log_every_s`` has elapsed; None otherwise."""
        if self.log_every_s is None:
            return None
        with self._lock:
            if (
                self._last_log is not None
                and now - self._last_log < self.log_every_s
            ):
                return None
            self._last_log = now
        snap = self.snapshot()
        line = (
            f"serve: {snap['completed']} done / {snap['in_flight']} "
            f"in-flight | wait p50={snap['queue_wait']['p50_s'] * 1e3:.2f}ms "
            f"p99={snap['queue_wait']['p99_s'] * 1e3:.2f}ms | exec "
            f"p50={snap['execute']['p50_s'] * 1e3:.2f}ms "
            f"p99={snap['execute']['p99_s'] * 1e3:.2f}ms | flushes "
            f"{dict(snap['flush_reasons'])}"
        )
        log.info(line)
        return line
