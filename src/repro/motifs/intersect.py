"""Tiled hyperedge-pair intersection kernels.

Motif classification (``repro.motifs.hmotifs``) reduces to one primitive:
given batches of hyperedge id pairs (or triples), return the size of the
member-set intersection.  This is exactly the clique-vs-bipartite tension
MESH §IV-A studies — clique expansion *precomputes* every pairwise
intersection while the bipartite incidence must derive them — so the
kernel ships two interchangeable paths behind one cost model:

* ``bitset`` — pack each hyperedge's member set into uint32 lanes
  (``[E, ceil(|V|/32)]``); an intersection is AND + popcount over the
  word lanes.  Dense, branch-free, MXU/VPU-shaped (the Pallas version
  lives in ``repro.kernels.isect``); wins for small vertex vocabularies
  where the word count stays below the sort-merge work.
* ``merge`` — pad each hyperedge's *sorted* member list to the max
  cardinality (built from the CSR arrays ``sorted_by_dst`` produces) and
  count membership via per-row ``searchsorted``.  O(K log K) per pair
  independent of |V|; wins for large vocabularies.

Both paths are jit-able and tiled (``lax.map`` over fixed-size pair
tiles, so peak memory is ``tile x max(W, K)`` regardless of batch size)
and both can tile across a device mesh (``shard_map`` over pair blocks,
each device reducing its slice — the sharded analytics backend).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.core.hypergraph import HyperGraph

INTERSECT_KERNELS = ("auto", "bitset", "merge")


@dataclasses.dataclass(frozen=True)
class PairIndex:
    """Preprocessed per-hyperedge member structure for one kernel path.

    ``data`` is ``[E, W] uint32`` bit lanes (bitset) or ``[E, K] int32``
    sorted members padded with the sentinel ``n_vertices`` (merge).
    """

    kind: str                 # "bitset" | "merge"
    n_vertices: int
    n_hyperedges: int
    data: jnp.ndarray

    @property
    def width(self) -> int:
        return int(self.data.shape[1])

    @property
    def nbytes(self) -> int:
        return int(self.data.size) * 4

    def cardinalities(self) -> np.ndarray:
        """|e| per hyperedge, recovered from the index itself."""
        if self.kind == "merge":
            return np.asarray(
                (np.asarray(self.data) < self.n_vertices).sum(axis=1),
                np.int64,
            )
        return np.asarray(
            jax.lax.population_count(self.data).astype(jnp.int32).sum(axis=1),
            np.int64,
        )


def _clean_incidence(hg: HyperGraph) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (src, dst) with masked incidences dropped and duplicate
    memberships collapsed (intersection counts are *set* sizes)."""
    src = np.asarray(hg.src)
    dst = np.asarray(hg.dst)
    if hg.e_mask is not None:
        keep = np.asarray(hg.e_mask) > 0
        src, dst = src[keep], dst[keep]
    if len(src) == 0:
        return src.astype(np.int32), dst.astype(np.int32)
    key = dst.astype(np.int64) * np.int64(max(hg.n_vertices, 1)) + src
    _, first = np.unique(key, return_index=True)
    return src[first].astype(np.int32), dst[first].astype(np.int32)


def build_index(hg: HyperGraph, kernel: str) -> PairIndex:
    """Build the per-hyperedge member structure for one kernel path
    (host-side preprocessing, like the representation builds of §IV-A)."""
    src, dst = _clean_incidence(hg)
    nv, ne = hg.n_vertices, hg.n_hyperedges
    if kernel == "bitset":
        w = max((nv + 31) // 32, 1)
        bits = np.zeros((max(ne, 1), w), np.uint32)
        if len(src):
            np.bitwise_or.at(
                bits,
                (dst, src >> 5),
                np.left_shift(np.uint32(1), (src & 31).astype(np.uint32)),
            )
        return PairIndex("bitset", nv, ne, jnp.asarray(bits))
    if kernel == "merge":
        if len(src):
            card = np.bincount(dst, minlength=ne)
            k = max(int(card.max()), 1)
        else:
            k = 1
        members = np.full((max(ne, 1), k), nv, np.int32)
        if len(src):
            order = np.lexsort((src, dst))
            s, d = src[order], dst[order]
            bounds = np.searchsorted(d, np.arange(ne + 1))
            pos = np.arange(len(s)) - bounds[d]
            members[d, pos] = s
        return PairIndex("merge", nv, ne, jnp.asarray(members))
    raise ValueError(
        f"unknown intersection kernel {kernel!r}; pick one of "
        f"{INTERSECT_KERNELS[1:]}"
    )


def select_intersect_kernel(
    hg: HyperGraph, *, bitset_budget_bytes: int = 256 << 20
) -> tuple[str, dict]:
    """Bitset vs sorted-merge for one hypergraph — the PR-1-style cost
    model.

    Per-pair work: bitset touches ``W = ceil(|V|/32)`` uint32 lanes;
    merge does ``K (log2 K + 1)`` compares for max cardinality ``K``.
    Small vocabularies keep ``W`` below the merge work (pick bitset);
    large vocabularies blow the word count (and the ``E x W`` index
    memory) up, so merge wins.
    """
    nv, ne = hg.n_vertices, hg.n_hyperedges
    card = np.asarray(hg.cardinalities())
    k = max(int(card.max()) if card.size else 1, 1)
    w = max((nv + 31) // 32, 1)
    bitset_cost = float(w)
    merge_cost = float(k * (math.log2(k) + 1.0))
    bitset_bytes = ne * w * 4
    why: dict[str, Any] = {
        "bitset_words_per_pair": w,
        "merge_ops_per_pair": merge_cost,
        "bitset_index_bytes": bitset_bytes,
        "bitset_budget_bytes": bitset_budget_bytes,
    }
    if bitset_bytes > bitset_budget_bytes:
        why["reason"] = "bitset index exceeds memory budget"
        return "merge", why
    if bitset_cost <= merge_cost:
        why["reason"] = "vocabulary small: word lanes beat sort-merge"
        return "bitset", why
    why["reason"] = "vocabulary large: sort-merge beats word lanes"
    return "merge", why


# --------------------------------------------------------------------------
# tile bodies (shared by the local and sharded drivers)
# --------------------------------------------------------------------------

def _tile_bitset(bits, a, b, c):
    inter = jnp.take(bits, a, axis=0) & jnp.take(bits, b, axis=0)
    if c is not None:
        inter = inter & jnp.take(bits, c, axis=0)
    return jax.lax.population_count(inter).astype(jnp.int32).sum(axis=-1)


def _tile_merge(members, nv, a, b, c):
    ra = jnp.take(members, a, axis=0)

    def contains(rows, probe):
        idx = jax.vmap(jnp.searchsorted)(rows, probe)
        idx = jnp.minimum(idx, rows.shape[1] - 1)
        return jnp.take_along_axis(rows, idx, axis=1) == probe

    hit = contains(jnp.take(members, b, axis=0), ra) & (ra < nv)
    if c is not None:
        hit = hit & contains(jnp.take(members, c, axis=0), ra)
    return hit.sum(axis=1).astype(jnp.int32)


@partial(jax.jit, static_argnames=("kind", "nv", "tile", "with_c"))
def _batch_tiled(data, ea, eb, ec, *, kind, nv, tile, with_c):
    """[n] pair/triple intersection sizes, n a static multiple of tile."""
    nt = ea.shape[0] // tile
    resh = lambda x: x.reshape(nt, tile)

    def body(args):
        a, b, c = args
        c = c if with_c else None
        if kind == "bitset":
            return _tile_bitset(data, a, b, c)
        return _tile_merge(data, nv, a, b, c)

    return jax.lax.map(body, (resh(ea), resh(eb), resh(ec))).reshape(-1)


def batch_intersections(
    index: PairIndex,
    ea,
    eb,
    ec=None,
    *,
    tile: int = 2048,
    mesh=None,
    axis: str = "data",
) -> np.ndarray:
    """Intersection size per (ea[i], eb[i]) pair — or per triple when
    ``ec`` is given.  Tiled locally; with a mesh, pair blocks are tiled
    across ``mesh[axis]`` (each device reduces its slice, the index is
    replicated) — the sharded batch-analytics backend.
    """
    ea = np.asarray(ea, np.int32)
    eb = np.asarray(eb, np.int32)
    n = len(ea)
    if n == 0:
        return np.zeros(0, np.int32)
    with_c = ec is not None
    ec = np.asarray(ec, np.int32) if with_c else np.zeros(n, np.int32)

    n_parts = int(mesh.shape[axis]) if mesh is not None else 1
    block = -(-n // (n_parts * tile)) * tile
    n_pad = block * n_parts
    pad = lambda x: np.pad(x, (0, n_pad - n)) if n_pad > n else x
    ea_p, eb_p, ec_p = map(
        jnp.asarray, (pad(ea), pad(eb), pad(ec))
    )
    kw = dict(kind=index.kind, nv=index.n_vertices, tile=tile,
              with_c=with_c)

    if mesh is None:
        out = _batch_tiled(index.data, ea_p, eb_p, ec_p, **kw)
        return np.asarray(out[:n])

    def run(data, a, b, c):
        return _batch_tiled(data, a, b, c, **kw)

    mapped = _shard_map(
        run,
        mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    with mesh:
        out = jax.jit(mapped)(index.data, ea_p, eb_p, ec_p)
    return np.asarray(out)[:n]
