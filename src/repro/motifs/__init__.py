"""Batch hypergraph analytics: hyperedge intersections and h-motifs.

The batch counterpart of the iterative superstep executor — MESH's
flexibility claim exercised on a workload with no supersteps at all.
Module map:

* ``intersect`` — the tiled hyperedge-pair intersection kernel: a
  dense-bitset path (uint32 vertex-id lanes, small vocabularies) and a
  sorted-merge path (``searchsorted`` over padded CSR member lists,
  large vocabularies), selected by ``select_intersect_kernel``; both
  tile locally (``lax.map``) and across a mesh (``shard_map`` pair
  blocks).
* ``hmotifs`` — the 26 h-motif classes (Lee et al. 2020), derived
  programmatically from the emptiness patterns of the 7 Venn regions of
  a hyperedge triple; connected-triple enumeration over the overlap
  graph; the exact census.
* ``sampling`` — the uniform linked-pair sampling estimator
  (MoCHy-A style) with normal-approximation confidence intervals.

Callers should route through ``Engine.analyze`` (``repro.core.executor``)
so representation / kernel / backend selection stays on the facade's
cost-model seam.
"""
from repro.motifs.hmotifs import (
    CLASS_OF_PATTERN,
    Census,
    N_HMOTIF_CLASSES,
    build_overlap_graph,
    classify_patterns,
    connected_triples,
    exact_census,
    materialize_pair_sizes,
    overlap_pairs,
    overlap_pairs_with_counts,
    pair_sizes_lookup,
)
from repro.motifs.intersect import (
    INTERSECT_KERNELS,
    PairIndex,
    batch_intersections,
    build_index,
    select_intersect_kernel,
)
from repro.motifs.sampling import CensusEstimate, sampled_census

__all__ = [
    "CLASS_OF_PATTERN",
    "Census",
    "CensusEstimate",
    "INTERSECT_KERNELS",
    "N_HMOTIF_CLASSES",
    "PairIndex",
    "batch_intersections",
    "build_index",
    "build_overlap_graph",
    "classify_patterns",
    "connected_triples",
    "exact_census",
    "materialize_pair_sizes",
    "overlap_pairs",
    "overlap_pairs_with_counts",
    "pair_sizes_lookup",
    "sampled_census",
    "select_intersect_kernel",
]
