"""Uniform pair-sampling h-motif estimator (MoCHy-A style).

Exact enumeration touches every connected triple — quadratic-plus in the
overlap degree, infeasible for the paper's heavy regimes.  The estimator
samples **linked hyperedge pairs** uniformly (with replacement) from the
``L`` edges of the overlap graph; for a sampled pair (a, b), every
completion c ∈ N(a) ∪ N(b) yields a connected triple.  A triple with
``k`` linked pairs among its three (k ∈ {2, 3}) is reachable from
exactly ``k`` sampled pairs, so crediting ``1/k`` per discovery and
scaling by ``L / s`` gives an unbiased census estimate:

    E[ L/s · Σ_samples Σ_triples 1/k · [class = m] ] = census[m].

Confidence intervals come from the sample variance of the per-draw
contributions (iid by construction, normal approximation).
"""
from __future__ import annotations

import dataclasses
from statistics import NormalDist

import numpy as np

from repro.core.hypergraph import HyperGraph
from repro.motifs.hmotifs import (
    N_HMOTIF_CLASSES,
    OverlapGraph,
    build_overlap_graph,
    classify_patterns,
    triple_profiles,
)
from repro.motifs.intersect import (
    PairIndex,
    build_index,
    select_intersect_kernel,
)


@dataclasses.dataclass(frozen=True)
class CensusEstimate:
    """Sampled census with per-class confidence intervals."""

    counts: np.ndarray     # [N_HMOTIF_CLASSES] float64 point estimates
    ci_low: np.ndarray
    ci_high: np.ndarray
    confidence: float
    n_samples: int
    n_pairs: int           # L: linked pairs in the overlap graph
    n_triples_seen: int    # triples classified across all samples

    @property
    def total(self) -> float:
        return float(self.counts.sum())


def sampled_census(
    hg: HyperGraph,
    n_samples: int,
    *,
    seed: int = 0,
    confidence: float = 0.95,
    index: PairIndex | None = None,
    kernel: str = "auto",
    tile: int = 2048,
    mesh=None,
    axis: str = "data",
    og: OverlapGraph | None = None,
    pair_sizes: dict | None = None,
) -> CensusEstimate:
    if index is None:
        if kernel == "auto":
            kernel, _ = select_intersect_kernel(hg)
        index = build_index(hg, kernel)
    if og is None:
        og = build_overlap_graph(hg)
    n_classes = N_HMOTIF_CLASSES
    zeros = np.zeros(n_classes)
    if og.n_pairs == 0 or n_samples <= 0:
        return CensusEstimate(
            counts=zeros, ci_low=zeros.copy(), ci_high=zeros.copy(),
            confidence=confidence, n_samples=n_samples,
            n_pairs=og.n_pairs, n_triples_seen=0,
        )

    rng = np.random.default_rng(seed)
    draws = rng.integers(0, og.n_pairs, size=n_samples)
    a, b = og.pairs[draws, 0], og.pairs[draws, 1]

    # Completions: every neighbor of either endpoint (dedup within one
    # sample — c can neighbor both a and b).
    rows_a, cand_a = og.neighbors_flat(a)
    rows_b, cand_b = og.neighbors_flat(b)
    rows = np.concatenate([rows_a, rows_b])
    cand = np.concatenate([cand_a, cand_b])
    keep = (cand != a[rows]) & (cand != b[rows])
    rows, cand = rows[keep], cand[keep]
    e = np.int64(hg.n_hyperedges)
    _, first = np.unique(rows.astype(np.int64) * e + cand,
                         return_index=True)
    rows, cand = rows[first], cand[first]

    if len(rows) == 0:
        return CensusEstimate(
            counts=zeros, ci_low=zeros.copy(), ci_high=zeros.copy(),
            confidence=confidence, n_samples=n_samples,
            n_pairs=og.n_pairs, n_triples_seen=0,
        )

    triples = np.stack([a[rows], b[rows], cand], axis=1).astype(np.int64)
    sa, sb, sc, iab, ibc, ica, iabc = triple_profiles(
        index, triples, tile=tile, mesh=mesh, axis=axis,
        pair_sizes=pair_sizes,
    )
    cls = classify_patterns(sa, sb, sc, iab, ibc, ica, iabc)
    k = (iab > 0).astype(np.int64) + (ibc > 0) + (ica > 0)

    valid = cls >= 0
    rows_v, cls_v, w_v = rows[valid], cls[valid], 1.0 / k[valid]

    # Per-draw per-class contributions Y_i[m] = Σ_t 1/k(t); estimator is
    # L · mean_i(Y_i); draws completing no triple contribute Y_i = 0.
    per_draw = np.zeros(n_samples * n_classes)
    np.add.at(per_draw, rows_v * n_classes + cls_v, w_v)
    per_draw = per_draw.reshape(n_samples, n_classes)
    mean = per_draw.mean(axis=0)
    scale = float(og.n_pairs)
    counts = scale * mean
    if n_samples > 1:
        var = per_draw.var(axis=0, ddof=1)
        z = NormalDist().inv_cdf(0.5 + confidence / 2.0)
        half = z * scale * np.sqrt(var / n_samples)
    else:
        half = np.full(n_classes, np.inf)
    return CensusEstimate(
        counts=counts,
        ci_low=np.maximum(counts - half, 0.0),
        ci_high=counts + half,
        confidence=confidence,
        n_samples=n_samples,
        n_pairs=og.n_pairs,
        n_triples_seen=int(valid.sum()),
    )
