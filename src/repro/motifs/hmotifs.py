r"""H-motif classification and the exact census.

An *h-motif* (Lee et al., "Hypergraph Motifs: Concepts, Algorithms, and
Discoveries", 2020) describes the overlap pattern of a connected triple
of distinct hyperedges {a, b, c} by the emptiness of the seven regions
of their Venn diagram:

    a\(b∪c), b\(a∪c), c\(a∪b), (a∩b)\c, (b∩c)\a, (c∩a)\b, a∩b∩c

Two triples have the same h-motif iff their emptiness patterns match up
to a permutation of the three hyperedges.  After dropping patterns that
cannot occur (an empty hyperedge, duplicate hyperedges, a disconnected
triple) exactly **26** equivalence classes remain — ``N_HMOTIF_CLASSES``
is derived programmatically below and asserted in the tests.

Every region size follows from seven intersection numbers
(|a|, |b|, |c|, |a∩b|, |b∩c|, |c∩a|, |a∩b∩c|) by inclusion–exclusion,
so the census is: enumerate connected triples (host-side, over the
hyperedge-overlap graph — the clique expansion of the *dual*
hypergraph), batch the intersection numbers through the tiled kernel
(``repro.motifs.intersect``), classify, histogram.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import numpy as np

from repro.core.hypergraph import HyperGraph
from repro.motifs.intersect import (
    PairIndex,
    _clean_incidence,
    batch_intersections,
    build_index,
    select_intersect_kernel,
)

# Region r in 1..7 is the Venn cell whose members belong exactly to the
# hyperedges named by the bits of r (bit 0 = a, bit 1 = b, bit 2 = c);
# an emptiness pattern packs "region r is non-empty" into bit r-1.
_N_PATTERNS = 128


def _permute_pattern(p: int, perm: tuple[int, int, int]) -> int:
    q = 0
    for r in range(1, 8):
        pr = 0
        for i in range(3):
            if (r >> i) & 1:
                pr |= 1 << perm[i]
        if (p >> (r - 1)) & 1:
            q |= 1 << (pr - 1)
    return q


def _pattern_valid(p: int) -> bool:
    """Can ``p`` be the pattern of a connected triple of distinct,
    non-empty hyperedges?"""
    regs = [r for r in range(1, 8) if (p >> (r - 1)) & 1]
    for x in range(3):
        if not any((r >> x) & 1 for r in regs):
            return False  # hyperedge x empty
    for x, y in ((0, 1), (0, 2), (1, 2)):
        if not any(((r >> x) & 1) != ((r >> y) & 1) for r in regs):
            return False  # no region distinguishes x from y: duplicates
    links = sum(
        any(((r >> x) & 1) and ((r >> y) & 1) for r in regs)
        for x, y in ((0, 1), (0, 2), (1, 2))
    )
    return links >= 2  # 3 nodes: ≥2 overlap links <=> connected


def _build_tables() -> tuple[np.ndarray, int]:
    perms = list(itertools.permutations(range(3)))
    canon = np.array(
        [min(_permute_pattern(p, pm) for pm in perms)
         for p in range(_N_PATTERNS)],
        np.int32,
    )
    classes = sorted(
        {int(canon[p]) for p in range(_N_PATTERNS) if _pattern_valid(p)}
    )
    class_of = np.full(_N_PATTERNS, -1, np.int32)
    for p in range(_N_PATTERNS):
        if _pattern_valid(p):
            class_of[p] = classes.index(int(canon[p]))
    return class_of, len(classes)


#: pattern -> h-motif class id (0..25), -1 for impossible patterns.
CLASS_OF_PATTERN, N_HMOTIF_CLASSES = _build_tables()


def classify_patterns(
    sa, sb, sc, iab, ibc, ica, iabc
) -> np.ndarray:
    """Map intersection numbers of (a, b, c) triples to h-motif class
    ids (vectorized; -1 = impossible, i.e. duplicate hyperedges)."""
    sa, sb, sc, iab, ibc, ica, iabc = (
        np.asarray(x, np.int64) for x in (sa, sb, sc, iab, ibc, ica, iabc)
    )
    abc = iabc
    ab = iab - iabc
    bc = ibc - iabc
    ca = ica - iabc
    a = sa - iab - ica + iabc
    b = sb - iab - ibc + iabc
    c = sc - ibc - ica + iabc
    pattern = (
        ((a > 0).astype(np.int32) << 0)
        | ((b > 0).astype(np.int32) << 1)
        | ((ab > 0).astype(np.int32) << 2)
        | ((c > 0).astype(np.int32) << 3)
        | ((ca > 0).astype(np.int32) << 4)
        | ((bc > 0).astype(np.int32) << 5)
        | ((abc > 0).astype(np.int32) << 6)
    )
    return CLASS_OF_PATTERN[pattern]


# --------------------------------------------------------------------------
# overlap graph + connected-triple enumeration (host-side preprocessing)
# --------------------------------------------------------------------------

def overlap_pairs_with_counts(
    hg: HyperGraph,
) -> tuple[np.ndarray, np.ndarray]:
    """``([L, 2], [L])`` hyperedge id pairs (a < b) sharing ≥ 1 vertex,
    plus the shared-vertex count |a∩b| per pair — the edge list (and
    edge attribute) of the clique expansion of the *dual* hypergraph.

    Vectorized by degree bucketing: vertices of equal degree d emit
    their C(d, 2) member pairs in one ``triu_indices`` shot, so the
    host-side loop runs over *distinct degrees*, not vertices.
    """
    src, dst = _clean_incidence(hg)
    if len(src) == 0:
        return np.zeros((0, 2), np.int64), np.zeros(0, np.int64)
    order = np.lexsort((dst, src))
    o, m = src[order], dst[order].astype(np.int64)
    counts = np.bincount(o, minlength=hg.n_vertices)
    starts = np.concatenate(([0], np.cumsum(counts)))[:-1]
    e = np.int64(hg.n_hyperedges)
    chunks = []
    for d in np.unique(counts):
        if d < 2:
            continue
        owners = np.where(counts == d)[0]
        rows = m[starts[owners][:, None] + np.arange(d)[None, :]]
        iu, ju = np.triu_indices(int(d), k=1)
        a, b = rows[:, iu].ravel(), rows[:, ju].ravel()
        chunks.append(np.minimum(a, b) * e + np.maximum(a, b))
    if not chunks:
        return np.zeros((0, 2), np.int64), np.zeros(0, np.int64)
    keys, n_shared = np.unique(np.concatenate(chunks), return_counts=True)
    pairs = np.stack([keys // e, keys % e], axis=1)
    return pairs, n_shared.astype(np.int64)


def overlap_pairs(hg: HyperGraph) -> np.ndarray:
    """``[L, 2]`` hyperedge id pairs (a < b) sharing at least one vertex
    — the edge list of the overlap (line) graph."""
    return overlap_pairs_with_counts(hg)[0]


@dataclasses.dataclass(frozen=True)
class OverlapGraph:
    """CSR adjacency over hyperedges sharing a vertex."""

    pairs: np.ndarray    # [L, 2] int64, a < b
    indptr: np.ndarray   # [E + 1]
    nbrs: np.ndarray     # [2L]

    @property
    def n_pairs(self) -> int:
        return int(self.pairs.shape[0])

    def neighbors_flat(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Concatenated neighbor lists for ``ids``; returns (owner row
        index per entry, neighbor id per entry)."""
        counts = self.indptr[ids + 1] - self.indptr[ids]
        starts = self.indptr[ids]
        total = int(counts.sum())
        flat = np.repeat(starts, counts)
        csum = np.concatenate(([0], np.cumsum(counts)[:-1]))
        flat = flat + (np.arange(total) - np.repeat(csum, counts))
        return np.repeat(np.arange(len(ids)), counts), self.nbrs[flat]


def build_overlap_graph(
    hg: HyperGraph, pairs: np.ndarray | None = None
) -> OverlapGraph:
    if pairs is None:
        pairs = overlap_pairs(hg)
    u = np.concatenate([pairs[:, 0], pairs[:, 1]])
    v = np.concatenate([pairs[:, 1], pairs[:, 0]])
    order = np.argsort(u, kind="stable")
    u, v = u[order], v[order]
    indptr = np.searchsorted(u, np.arange(hg.n_hyperedges + 1))
    return OverlapGraph(pairs=pairs, indptr=indptr, nbrs=v)


def connected_triples(og: OverlapGraph, n_hyperedges: int) -> np.ndarray:
    """``[T, 3]`` sorted hyperedge id triples whose overlap graph is
    connected (each triple exactly once)."""
    if og.n_pairs == 0:
        return np.zeros((0, 3), np.int64)
    if n_hyperedges >= (1 << 21):
        raise ValueError(
            "exact census enumeration needs n_hyperedges < 2^21; use the "
            "sampling estimator"
        )
    a, b = og.pairs[:, 0], og.pairs[:, 1]
    rows_a, cand_a = og.neighbors_flat(a)
    rows_b, cand_b = og.neighbors_flat(b)
    rows = np.concatenate([rows_a, rows_b])
    cand = np.concatenate([cand_a, cand_b])
    keep = (cand != a[rows]) & (cand != b[rows])
    rows, cand = rows[keep], cand[keep]
    tri = np.sort(
        np.stack([a[rows], b[rows], cand], axis=1), axis=1
    ).astype(np.int64)
    e = np.int64(n_hyperedges)
    key = (tri[:, 0] * e + tri[:, 1]) * e + tri[:, 2]
    _, first = np.unique(key, return_index=True)
    return tri[first]


# --------------------------------------------------------------------------
# exact census
# --------------------------------------------------------------------------

def triple_profiles(
    index: PairIndex,
    triples: np.ndarray,
    *,
    tile: int = 2048,
    mesh=None,
    axis: str = "data",
    pair_sizes: dict | None = None,
) -> tuple[np.ndarray, ...]:
    """The 7 intersection numbers per triple, via the batch kernel.

    ``pair_sizes`` optionally maps encoded (a<b) pair keys to
    materialized intersection sizes (the dual-clique-expansion path);
    pairs found there skip the kernel.
    """
    a, b, c = triples[:, 0], triples[:, 1], triples[:, 2]
    card = index.cardinalities()
    sa, sb, sc = card[a], card[b], card[c]

    def pair_counts(x, y):
        if pair_sizes is not None:
            e = np.int64(index.n_hyperedges)
            lo, hi = np.minimum(x, y), np.maximum(x, y)
            return pair_sizes_lookup(pair_sizes, lo * e + hi)
        return batch_intersections(
            index, x, y, tile=tile, mesh=mesh, axis=axis
        ).astype(np.int64)

    iab = pair_counts(a, b)
    ibc = pair_counts(b, c)
    ica = pair_counts(c, a)
    iabc = batch_intersections(
        index, a, b, c, tile=tile, mesh=mesh, axis=axis
    ).astype(np.int64)
    return sa, sb, sc, iab, ibc, ica, iabc


def pair_sizes_lookup(pair_sizes: dict, keys: np.ndarray) -> np.ndarray:
    sorted_keys, sizes = pair_sizes["keys"], pair_sizes["sizes"]
    pos = np.searchsorted(sorted_keys, keys)
    pos = np.minimum(pos, max(len(sorted_keys) - 1, 0))
    hit = sorted_keys[pos] == keys if len(sorted_keys) else np.zeros(
        len(keys), bool
    )
    out = np.where(hit, sizes[pos] if len(sizes) else 0, 0)
    return out.astype(np.int64)


def materialize_pair_sizes(
    hg: HyperGraph,
    pairs: np.ndarray | None = None,
    n_shared: np.ndarray | None = None,
) -> dict:
    """Precompute |a∩b| for every overlapping pair — what the clique
    expansion of the dual hypergraph materializes (§IV-A's
    representation tradeoff, applied to batch analytics).  Lookups for
    *distinct* pairs absent from the table are 0 — exact, since absence
    means the pair shares no vertex.  The table holds a < b pairs only:
    self-pairs (|e ∩ e| = |e|) are the caller's job."""
    if pairs is None or n_shared is None:
        pairs, n_shared = overlap_pairs_with_counts(hg)
    e = np.int64(hg.n_hyperedges)
    return {"keys": pairs[:, 0] * e + pairs[:, 1], "sizes": n_shared}


@dataclasses.dataclass(frozen=True)
class Census:
    """Exact h-motif census."""

    counts: np.ndarray          # [N_HMOTIF_CLASSES] int64
    n_triples: int              # connected triples classified
    n_duplicate_triples: int    # triples dropped (duplicate hyperedges)
    n_pairs: int                # overlapping hyperedge pairs examined

    @property
    def total(self) -> int:
        return int(self.counts.sum())


def exact_census(
    hg: HyperGraph,
    *,
    index: PairIndex | None = None,
    kernel: str = "auto",
    tile: int = 2048,
    mesh=None,
    axis: str = "data",
    pair_sizes: dict | None = None,
    og: OverlapGraph | None = None,
) -> Census:
    """Enumerate and classify every connected 3-hyperedge pattern."""
    if index is None:
        if kernel == "auto":
            kernel, _ = select_intersect_kernel(hg)
        index = build_index(hg, kernel)
    if og is None:
        og = build_overlap_graph(hg)
    triples = connected_triples(og, hg.n_hyperedges)
    if len(triples) == 0:
        return Census(
            counts=np.zeros(N_HMOTIF_CLASSES, np.int64),
            n_triples=0, n_duplicate_triples=0, n_pairs=og.n_pairs,
        )
    cls = classify_patterns(
        *triple_profiles(
            index, triples, tile=tile, mesh=mesh, axis=axis,
            pair_sizes=pair_sizes,
        )
    )
    valid = cls >= 0
    counts = np.bincount(cls[valid], minlength=N_HMOTIF_CLASSES).astype(
        np.int64
    )
    return Census(
        counts=counts,
        n_triples=int(valid.sum()),
        n_duplicate_triples=int((~valid).sum()),
        n_pairs=og.n_pairs,
    )
