"""Fused incidence delivery — the delivery-kernel registry.

``repro.core.engine.deliver`` routes through here when a
``DeliveryLayout`` is supplied (the ``delivery='pallas_fused'`` design
point).  One fused data path, two lowerings:

* ``pallas`` — the scalar-prefetch gather + mask + segment-combine
  kernel (``fused.deliver_fused_pallas``), native on TPU, exercised in
  interpret mode by the test suite;
* ``ell`` — the identical layout driven through stock XLA ops
  (``xla.deliver_ell_leaf``): dense ELL reduce + sorted-COO overflow,
  the fast path on hosts without a native Pallas backend.

``select_lowering`` picks per platform; ``REPRO_DELIVERY_LOWERING``
(``ell`` | ``pallas`` | ``pallas_interpret``) overrides for tests and
experiments.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.deliver.fused import (
    deliver_fused_classes,
    deliver_fused_pallas,
)
from repro.kernels.deliver.layout import (
    ClassPlan,
    DeliveryLayout,
    build_delivery_layout,
    classify_degrees,
    layout_pair,
    plan_degree_classes,
    plan_ell_width,
    tile_block_bounds,
)
from repro.kernels.deliver.xla import deliver_ell_leaf
from repro.sparse.segment import MONOIDS

__all__ = [
    "DELIVERY_MODES",
    "ClassPlan",
    "DeliveryLayout",
    "build_delivery_layout",
    "classify_degrees",
    "deliver_ell_leaf",
    "deliver_fused_classes",
    "deliver_fused_pallas",
    "fused_deliver",
    "layout_pair",
    "plan_degree_classes",
    "plan_ell_width",
    "select_lowering",
    "tile_block_bounds",
]

# The ``ExecutionConfig.delivery`` axis values.
DELIVERY_MODES = ("auto", "xla", "pallas_fused")

Pytree = Any


def select_lowering() -> str:
    """``pallas`` on TPU, ``ell`` elsewhere; env-overridable."""
    forced = os.environ.get("REPRO_DELIVERY_LOWERING")
    if forced:
        if forced not in ("ell", "pallas", "pallas_interpret"):
            raise ValueError(
                "REPRO_DELIVERY_LOWERING must be ell | pallas | "
                f"pallas_interpret, got {forced!r}"
            )
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ell"


def _pallas_leaf(leaf, layout, monoid, active, *, interpret):
    """Shape-normalize one leaf for the per-class 2-D Pallas kernels."""
    shape = leaf.shape
    msgs2d = leaf.reshape(shape[0], -1)
    if monoid.name == "or":
        # bool has no MXU contraction: lower "or" as int32 max.
        out = _pallas_leaf(
            msgs2d.astype(jnp.int32), layout, MONOIDS["max"], active,
            interpret=interpret,
        )
        # > 0, not astype(bool): empty destinations hold the max
        # identity (iinfo.min), which must read back as False.
        return (out > 0).reshape((layout.n_dst,) + shape[1:])
    ident = monoid.identity(msgs2d.dtype)
    msgs_aug = jnp.concatenate(
        [msgs2d, jnp.full((1, msgs2d.shape[1]), ident, msgs2d.dtype)]
    )
    act_aug = None
    if active is not None:
        act_aug = jnp.concatenate(
            [active.astype(jnp.int32), jnp.ones((1,), jnp.int32)]
        )
    out = deliver_fused_classes(
        msgs_aug, act_aug, layout, monoid.name, interpret=interpret
    )
    return out.reshape((layout.n_dst,) + shape[1:])


def fused_deliver(
    out_msg: Pytree,
    active,
    layout: DeliveryLayout,
    program,
    lowering: str | None = None,
) -> Pytree:
    """Deliver + combine a message pytree through the fused layout.

    Drop-in for the reference gather/mask/segment path of
    ``repro.core.engine.deliver`` on the monoid fast path (the caller
    guarantees ``program.reducer is None`` and no ``edge_transform``);
    per-leaf monoids resolve exactly as in the reference.
    """
    lowering = lowering or select_lowering()

    def one(leaf):
        monoid = program.monoid_for(leaf)
        if lowering == "ell":
            return deliver_ell_leaf(leaf, layout, monoid, active)
        return _pallas_leaf(
            leaf, layout, monoid, active,
            interpret=(lowering == "pallas_interpret"),
        )

    return jax.tree.map(one, out_msg)
