"""Fused incidence delivery: gather + mask + segment-combine in one
Pallas kernel over a dst-sorted, degree-classed CSR layout.

The reference delivery path (gather -> ``where`` mask -> segment reduce)
materializes a ``[nnz, D]`` rows array in HBM and re-reads it — ~3x the
traffic the combine fundamentally needs, plus a serialized scatter.
This kernel runs the whole half-superstep data path per output tile:

    for edge block b incident to destination tile i (block-sparse skip):
        rows   = msgs[sorted_src[b]]            # gather, in VMEM
        hit    = dst in tile i  AND  dynamically live
        out[i] = combine(out[i], mask_to_identity(rows, hit))

Message rows stream through VMEM once; the ``[nnz, D]`` intermediate
never exists.  Two combine lowerings:

* ``sum`` (and ``or`` via int cast outside): a ``[BN, BE]`` one-hot
  built with ``broadcasted_iota`` + compare contracts against the
  gathered rows on the MXU (fp32-friendly systolic work — the segsum
  kernel's trick, but fed by the in-kernel gather);
* ``min`` / ``max`` / ``prod``: a masked ``[BN, BE, D]`` select reduced
  on the VPU (no matmul identity exists), so ``block_e x block_d`` must
  be sized to VMEM.

Block-sparse skip: grid is ``(n_dst_tiles, max_blocks)``; a
scalar-prefetched ``[n_tiles, 2]`` table (from
``layout.tile_block_bounds``, i.e. CSR row offsets at ``block_e``
granularity) gives each tile its first edge block and block count, so a
tile only ever reads its incident edges — unlike the segsum kernel's
full j-sweep, work scales with the tile's degree sum, not with nnz.

Degree classes (``deliver_fused_classes``): heavy-tailed degree
distributions inflate a single grid's ``max_blocks`` to the hub tile's
block count — every tail tile then pays the hub's grid extent in
skipped steps.  The degree-class layout runs ONE ``pallas_call`` per
class over the class's own destination rows, with class-local
``block_e`` and ``max_blocks``; the per-class partial outputs
concatenate and assemble through the layout's ``inv_perm`` gather.
The CSR form has no width cap, so the Pallas path needs no residual.

Static liveness (``e_mask``) is folded into the layout (dead lanes are
dropped from the class edge lists); only the dynamic ``active`` vector
costs a per-edge mask at runtime.

The kernel is written for TPU (scalar prefetch via
``pltpu.PrefetchScalarGridSpec``; in-kernel row gather) and validated
on CPU in interpret mode; ``repro.kernels.deliver.xla`` is the
equivalent fused data path expressed to XLA for hosts without a native
Pallas backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.sparse.segment import resolve_monoid

# Monoids whose combine the kernel can lower (sum via MXU one-hot
# contraction, the rest via masked select-reduce).  "or" is handled by
# the wrapper as an int32 max.
_MATMUL_MONOIDS = ("sum",)
_SELECT_MONOIDS = ("min", "max", "prod")


def _combine_kernel(
    bounds_ref, src_ref, dst_ref, live_ref, msg_ref, out_ref,
    *, block_n: int, monoid_name: str,
):
    i = pl.program_id(0)  # destination tile
    j = pl.program_id(1)  # local edge-block index within this tile
    monoid = resolve_monoid(monoid_name)
    ident = monoid.identity(out_ref.dtype)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    n_blocks = bounds_ref[i, 1]

    @pl.when(j < n_blocks)
    def _accumulate():
        src = src_ref[...]                    # [BE] int32 (dst-sorted)
        dst = dst_ref[...]                    # [BE] int32 (non-decreasing)
        live = live_ref[...] != 0             # [BE] dynamic activity
        # THE fused gather: message rows land directly in VMEM registers,
        # never in an HBM-resident [nnz, D] intermediate.
        rows = jnp.take(msg_ref[...], src, axis=0)     # [BE, D]

        base = i * block_n
        local = dst - base
        iota = jax.lax.broadcasted_iota(
            jnp.int32, (block_n, src.shape[0]), 0
        )
        # [BN, BE]: edge e feeds local destination row (boundary blocks
        # carry neighbors' edges -> masked off here, not re-read).
        hit = (iota == local[None, :]) & live[None, :]

        if monoid_name in _MATMUL_MONOIDS:
            onehot = hit.astype(rows.dtype)
            out_ref[...] += jax.lax.dot_general(
                onehot, rows,
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=out_ref.dtype,
            )
        else:
            picked = jnp.where(
                hit[:, :, None], rows[None, :, :], ident
            )                                  # [BN, BE, D] in VMEM
            reduced = {
                "min": jnp.min, "max": jnp.max, "prod": jnp.prod,
            }[monoid_name](picked, axis=1)
            out_ref[...] = monoid.combine(out_ref[...], reduced)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_dst", "monoid_name", "max_blocks", "block_n", "block_e",
        "interpret",
    ),
)
def deliver_fused_pallas(
    msgs_aug: jnp.ndarray,
    sorted_src: jnp.ndarray,
    sorted_dst: jnp.ndarray,
    live: jnp.ndarray,
    tile_bounds: jnp.ndarray,
    n_dst: int,
    monoid_name: str,
    max_blocks: int = 1,
    *,
    block_n: int = 128,
    block_e: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """One leaf's fused delivery over a prepared dst-sorted layout.

    msgs_aug: ``[n_src + 1, D]`` — messages with the monoid identity row
      appended (index ``n_src``; statically-dead lanes point there).
    sorted_src / sorted_dst: ``[nnz_pad]`` int32, dst-sorted, padded to
      a ``block_e`` multiple (padding: identity row / out-of-range dst).
    live: ``[nnz_pad]`` int32 — dynamic activity per lane (1 = live).
    tile_bounds: ``[n_tiles, 2]`` int32 (first block, n blocks) per
      ``block_n``-destination tile — scalar-prefetched for the skip.
    max_blocks: static grid extent — the widest tile's block count (one
      entry of ``DeliveryLayout.class_max_blocks``; ``deliver_fused_classes``
      passes each class's own).

    Returns ``[n_dst, D]`` combined messages.
    """
    nnz_pad = sorted_src.shape[0]
    assert nnz_pad % block_e == 0, (nnz_pad, block_e)
    d = msgs_aug.shape[1]
    n_src_aug = msgs_aug.shape[0]
    n_dst_pad = -(-max(n_dst, 1) // block_n) * block_n
    n_tiles = n_dst_pad // block_n
    assert tile_bounds.shape == (n_tiles, 2), (
        tile_bounds.shape, n_tiles,
    )
    total_blocks = nnz_pad // block_e
    max_blocks = max(int(max_blocks), 1)

    def edge_map(i, j, b):
        start = b[i, 0]
        nb = b[i, 1]
        # Clamp: steps past this tile's range (and empty tiles) map to a
        # valid block; the kernel's ``j < nb`` guard skips the work.
        safe = start + jnp.minimum(j, jnp.maximum(nb - 1, 0))
        return (jnp.clip(safe, 0, total_blocks - 1),)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, max_blocks),
        in_specs=[
            pl.BlockSpec((block_e,), edge_map),
            pl.BlockSpec((block_e,), edge_map),
            pl.BlockSpec((block_e,), edge_map),
            pl.BlockSpec((n_src_aug, d), lambda i, j, b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j, b: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(
            _combine_kernel, block_n=block_n, monoid_name=monoid_name
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_dst_pad, d), msgs_aug.dtype),
        interpret=interpret,
    )(tile_bounds, sorted_src, sorted_dst, live, msgs_aug)
    return out[:n_dst]


def deliver_fused_classes(
    msgs_aug: jnp.ndarray,
    act_aug: jnp.ndarray | None,
    layout,
    monoid_name: str,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """One leaf's fused delivery over a degree-classed layout: one
    per-class Pallas grid each, assembled with the ``inv_perm`` gather.

    msgs_aug: ``[n_src + 1, D]`` — messages with the monoid identity row
      appended (index ``n_src``; padding lanes point there).
    act_aug: optional ``[n_src + 1]`` int32 dynamic activity (identity
      row live), or None.

    Returns ``[n_dst, D]`` combined messages.
    """
    outs = []
    for c in range(layout.n_classes):
        src_c = layout.class_src[c]
        live = (
            jnp.take(act_aug, src_c, axis=0)
            if act_aug is not None
            else jnp.ones_like(src_c)
        )
        outs.append(
            deliver_fused_pallas(
                msgs_aug,
                src_c,
                layout.class_dst[c],
                live,
                layout.class_bounds[c],
                layout.class_rows[c],
                monoid_name,
                layout.class_max_blocks[c],
                block_n=layout.block_n,
                block_e=layout.class_block_e[c],
                interpret=interpret,
            )
        )
    # Class partials stack class-major (matching slot assignment); the
    # appended identity row serves every zero-degree destination.
    return jnp.take(
        jnp.concatenate(outs + [msgs_aug[-1:]], axis=0),
        layout.inv_perm, axis=0,
    )
