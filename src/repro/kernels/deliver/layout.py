"""Dst-sorted CSR delivery layouts: the precompute behind fused delivery.

The deliver/combine half-superstep is MESH's hot path.  Its reference
lowering (``repro.core.engine.deliver``) is gather -> mask -> segment
reduce, which materializes a ``[nnz, D]`` rows array in HBM and re-reads
it — roughly 3x the traffic the combine fundamentally needs.  The fused
path removes that intermediate by reorganizing the incidence ONCE, on the
host, into a destination-sorted CSR layout:

* ``order`` — the *stable* dst-sort permutation (stability keeps each
  segment's rows in original incidence order, so reduction order — and
  therefore bitwise results for order-sensitive float sums — matches the
  reference scatter path);
* ``row_offsets`` — CSR offsets per destination, from which the Pallas
  kernel derives per-output-tile *edge-block bounds* (block-sparse skip:
  each grid step reads only its incident edge blocks, never a full
  j-sweep);
* an ELL + sorted-remainder packing for the XLA lowering on hosts
  without a native Pallas backend: the first ``k`` incidences of every
  destination live in a dense ``[n_dst, k]`` id table (reduced with one
  vectorized dense reduction — no serialized scatter), overflow
  incidences of heavy destinations stay in dst-sorted COO and take a
  sorted segment reduce.

Statically-dead incidences (``e_mask == 0`` — partition padding, bucket
padding) are folded into the layout itself: their table entries point at
the appended *identity row* ``n_src``, so the runtime path never touches
a mask for them.  Only dynamic ``active`` vectors cost work at runtime.

Everything here is host-side numpy on concrete arrays; the products are
device arrays registered as one pytree (``DeliveryLayout``) so layouts
flow through jit / scan / vmap / shard_map as ordinary operands.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ELL planning: grow k (powers of two) until the COO remainder holds at
# most this fraction of the incidences, then stop at the cap — heavy
# destinations past the cap are better served by the remainder's sorted
# segment reduce than by padding every destination to their degree.
ELL_REMAINDER_FRACTION = 0.25
ELL_K_CAP = 64
# Remainder / padded-edge buckets: pow2 with a small floor, mirroring
# ``repro.core.serving.bucket_dim`` so serving signatures stay bounded.
_PAD_FLOOR = 8


def _pow2_at_least(n: int, floor: int = 1) -> int:
    b = max(int(floor), 1)
    while b < n:
        b *= 2
    return b


def plan_ell_width(degrees: np.ndarray, nnz: int) -> tuple[int, int]:
    """Pick the ELL width ``k`` for a degree distribution.

    Returns ``(k, remainder)``: the smallest power-of-two ``k`` (capped
    at ``ELL_K_CAP``) whose overflow — incidences past each
    destination's first ``k`` — is at most ``ELL_REMAINDER_FRACTION`` of
    ``nnz``, plus the overflow count at that ``k``.  Deterministic in
    the degree histogram, so the Engine's cost model and the layout
    builder can never disagree.
    """
    if nnz <= 0 or degrees.size == 0:
        return 1, 0
    k = 1
    while True:
        remainder = int(np.maximum(degrees - k, 0).sum())
        if remainder <= ELL_REMAINDER_FRACTION * nnz or k >= ELL_K_CAP:
            return k, remainder
        k *= 2


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeliveryLayout:
    """One direction's precomputed fused-delivery layout.

    Array children (device arrays; leading dims may gain a partition dim
    under the distributed executor):

      sorted_src: ``[nnz_pad]`` int32 — sender ids in dst-sorted order;
        statically-dead and padding lanes point at the identity row
        ``n_src``.
      sorted_dst: ``[nnz_pad]`` int32 — destination ids, non-decreasing;
        padding lanes carry ``n_dst`` (no real destination).
      ell_idx: ``[n_dst, k]`` int32 — first-``k`` sender ids per
        destination; empty slots point at the identity row.
      rem_src / rem_dst: ``[rem_pad]`` int32 — overflow incidences in
        dst-sorted COO (padding lanes: identity row -> last destination,
        keeping ``rem_dst`` sorted; they contribute the monoid identity).
      tile_bounds: ``[n_tiles, 2]`` int32 — per output tile of
        ``block_n`` destinations: (first edge-block index, n edge
        blocks) at ``block_e`` granularity.  The Pallas kernel's
        block-sparse skip; recomputed by ``with_tile_geometry`` when a
        caller needs a different tiling.

    Static aux: ``n_src``, ``n_dst``, ``nnz`` (real incidences),
    ``block_n``, ``block_e``, ``max_blocks`` (grid extent of the skip).
    """

    sorted_src: jnp.ndarray
    sorted_dst: jnp.ndarray
    ell_idx: jnp.ndarray
    rem_src: jnp.ndarray
    rem_dst: jnp.ndarray
    tile_bounds: jnp.ndarray
    n_src: int
    n_dst: int
    nnz: int
    block_n: int
    block_e: int
    max_blocks: int

    def tree_flatten(self):
        children = (
            self.sorted_src, self.sorted_dst, self.ell_idx,
            self.rem_src, self.rem_dst, self.tile_bounds,
        )
        aux = (
            self.n_src, self.n_dst, self.nnz, self.block_n, self.block_e,
            self.max_blocks,
        )
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def k(self) -> int:
        return int(self.ell_idx.shape[-1])

    @property
    def rem_len(self) -> int:
        return int(self.rem_src.shape[-1])

    def shape_signature(self) -> tuple:
        """Hashable shape tuple for the serving executable cache key."""
        return (
            tuple(self.sorted_src.shape), tuple(self.ell_idx.shape),
            tuple(self.rem_src.shape), tuple(self.tile_bounds.shape),
            self.n_src, self.n_dst, self.nnz,
        )


def tile_block_bounds(
    row_offsets: np.ndarray, n_dst_pad: int, block_n: int, block_e: int
) -> tuple[np.ndarray, int]:
    """Per-output-tile edge-block ranges from CSR row offsets.

    Tile ``i`` covers destinations ``[i*block_n, (i+1)*block_n)``; its
    incident edges are CSR rows ``[row_offsets[lo], row_offsets[hi])``,
    which span edge blocks ``[floor(lo_e/block_e), ceil(hi_e/block_e))``.
    Boundary blocks contain neighbors' edges; the kernel masks them by
    destination.  Returns ``([n_tiles, 2] (start, count), max_count)``.
    """
    n_tiles = n_dst_pad // block_n
    bounds = np.zeros((n_tiles, 2), np.int32)
    n_real = len(row_offsets) - 1
    for i in range(n_tiles):
        lo = row_offsets[min(i * block_n, n_real)]
        hi = row_offsets[min((i + 1) * block_n, n_real)]
        b_lo = lo // block_e
        b_hi = -(-hi // block_e)
        bounds[i] = (b_lo, max(b_hi - b_lo, 0))
    max_blocks = int(bounds[:, 1].max()) if n_tiles else 0
    return bounds, max(max_blocks, 1)


def build_delivery_layout(
    src,
    dst,
    e_mask,
    n_src: int,
    n_dst: int,
    *,
    k: int | None = None,
    block_n: int = 128,
    block_e: int = 256,
    pad_sorted_to: int | None = None,
    rem_pad_to: int | None = None,
) -> DeliveryLayout:
    """Build one direction's layout from a concrete incidence list.

    ``src``/``dst``/``e_mask`` are host-transferable arrays (``e_mask``
    may be None).  ``k=None`` lets ``plan_ell_width`` pick the ELL width
    from the live-degree histogram.  ``pad_sorted_to`` pads the sorted
    edge arrays (identity lanes) so same-bucket hypergraphs share one
    executable signature; it must be >= nnz.  ``rem_pad_to`` forces the
    remainder pad length (>= the overflow count) so per-shard layouts
    stack into one shard_map operand.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    nnz = len(src)
    live = (
        np.asarray(e_mask) != 0
        if e_mask is not None
        else np.ones(nnz, bool)
    )

    order = np.argsort(dst, kind="stable")
    s_src = src[order]
    s_dst = dst[order]
    s_live = live[order]
    # Fold the static mask into the ids: dead incidences gather the
    # appended identity row and deliver the monoid identity for free.
    red_src = np.where(s_live, s_src, n_src).astype(np.int32)

    live_deg = np.bincount(
        s_dst[s_live], minlength=max(n_dst, 1)
    )[:n_dst] if nnz else np.zeros(max(n_dst, 1), np.int64)[:n_dst]
    n_live = int(s_live.sum())
    if k is None:
        k, _ = plan_ell_width(live_deg, n_live)
    k = max(int(k), 1)

    # ELL pack (first k live incidences per destination) + overflow COO.
    # Vectorized: each live incidence's rank within its (sorted, stable)
    # segment decides its slot — rank < k lands in the dense table,
    # rank >= k overflows to the dst-sorted remainder.
    ell = np.full((n_dst, k), n_src, np.int32)
    counts = np.bincount(s_dst, minlength=max(n_dst, 1))[
        : max(n_dst, 1)
    ]
    seg_starts = np.zeros(max(n_dst, 1) + 1, np.int64)
    np.cumsum(counts, out=seg_starts[1:])
    if nnz:
        live_cum = np.cumsum(s_live)
        live_before = np.concatenate([[0], live_cum])[
            seg_starts[s_dst]
        ]
        live_rank = live_cum - 1 - live_before  # valid on live lanes
        in_ell = s_live & (live_rank < k)
        ell[s_dst[in_ell], live_rank[in_ell]] = red_src[in_ell]
        overflow = s_live & (live_rank >= k)
        rem_s = red_src[overflow]
        rem_d = s_dst[overflow]  # still sorted: overflow preserves order
    else:
        rem_s = np.zeros(0, np.int32)
        rem_d = np.zeros(0, np.int64)
    if rem_pad_to is not None:
        assert rem_pad_to >= len(rem_s), (rem_pad_to, len(rem_s))
        rem_pad = int(rem_pad_to)
    else:
        rem_pad = _pow2_at_least(max(len(rem_s), 1), _PAD_FLOOR)
    rem_src = np.full(rem_pad, n_src, np.int32)
    # Padding remainder lanes keep rem_dst sorted by pointing at the
    # last destination with an identity sender (contributes nothing).
    rem_dst = np.full(rem_pad, max(n_dst - 1, 0), np.int32)
    rem_src[: len(rem_s)] = rem_s
    rem_dst[: len(rem_d)] = rem_d

    # Sorted edge arrays for the Pallas kernel, padded to the block /
    # bucket size; padding lanes: identity sender, out-of-range dst.
    nnz_pad = pad_sorted_to if pad_sorted_to is not None else nnz
    assert nnz_pad >= nnz, (nnz_pad, nnz)
    nnz_pad = -(-max(nnz_pad, 1) // block_e) * block_e
    n_dst_pad = -(-max(n_dst, 1) // block_n) * block_n
    sorted_src = np.full(nnz_pad, n_src, np.int32)
    sorted_dst = np.full(nnz_pad, n_dst_pad, np.int32)
    sorted_src[:nnz] = red_src
    sorted_dst[:nnz] = s_dst

    row_offsets = seg_starts[: n_dst + 1]
    bounds, max_blocks = tile_block_bounds(
        row_offsets, n_dst_pad, block_n, block_e
    )

    return DeliveryLayout(
        sorted_src=jnp.asarray(sorted_src),
        sorted_dst=jnp.asarray(sorted_dst),
        ell_idx=jnp.asarray(ell),
        rem_src=jnp.asarray(rem_src),
        rem_dst=jnp.asarray(rem_dst),
        tile_bounds=jnp.asarray(bounds),
        n_src=int(n_src),
        n_dst=int(n_dst),
        nnz=int(nnz),
        block_n=int(block_n),
        block_e=int(block_e),
        max_blocks=int(max_blocks),
    )


def layout_pair(
    hg_src, hg_dst, e_mask, n_vertices: int, n_hyperedges: int, **kw
) -> tuple[DeliveryLayout, DeliveryLayout]:
    """Both half-superstep directions for one incidence list:
    vertex->hyperedge (combine by ``dst``) and hyperedge->vertex
    (combine by ``src``)."""
    fwd = build_delivery_layout(
        hg_src, hg_dst, e_mask, n_vertices, n_hyperedges, **kw
    )
    bwd = build_delivery_layout(
        hg_dst, hg_src, e_mask, n_hyperedges, n_vertices, **kw
    )
    return fwd, bwd


Pytree = Any
