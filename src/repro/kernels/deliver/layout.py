"""Dst-sorted degree-class (sliced-ELL) delivery layouts: the precompute
behind fused delivery.

The deliver/combine half-superstep is MESH's hot path.  Its reference
lowering (``repro.core.engine.deliver``) is gather -> mask -> segment
reduce, which materializes a ``[nnz, D]`` rows array in HBM and re-reads
it — roughly 3x the traffic the combine fundamentally needs.  The fused
path removes that intermediate by reorganizing the incidence ONCE, on the
host, into a destination-sorted layout.

Real hypergraphs are heavy-tailed (power-law degrees and cardinalities),
so a single ELL width cannot serve both a mega-hub and the long tail:
capped at ``k``, a hub spills almost all of its incidences into an
overflow scatter; sized for the hub, the tail drowns in padding.  The
layout here is therefore **degree-classed** (SELL-style): destinations
are partitioned into a few contiguous *degree classes*, each with its own
power-of-two ELL width:

* ``plan_degree_classes`` picks 1–``MAX_CLASSES`` class boundaries from
  the live-degree histogram by dynamic programming over candidate
  power-of-two widths, minimizing dense padding plus (weighted) residual
  spill.  The plan is a pure function of the histogram, so the Engine's
  cost model and this builder can never disagree.
* Destinations are permuted class-major (ascending id within a class);
  ``inv_perm`` maps destination id -> its slot in the concatenated
  per-class outputs, so results assemble with one gather — never a
  scatter.  Zero-degree destinations (bucket padding!) own no slot at
  all: they point at an appended identity row.
* Per class, two synchronized packings of the same dst-sorted edges:
  a dense ``[rows_c, k_c]`` ELL id table (the XLA lowering's vectorized
  axis reduce) and a CSR-with-tile-bounds edge list (the Pallas kernel's
  block-sparse skip, with class-local ``block_e``/grid extents).
* Incidences past a hub's class width land in a small dst-sorted COO
  residual (XLA lowering only — the Pallas CSR form has no width cap)
  and take one sorted segment reduce.

Statically-dead incidences (``e_mask == 0`` — partition padding, bucket
padding) are dropped from every packing at build time; only dynamic
``active`` vectors cost work at runtime.

Everything here is host-side numpy on concrete arrays; the products are
device arrays registered as one pytree (``DeliveryLayout``) so layouts
flow through jit / scan / vmap / shard_map as ordinary operands.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import default_registry

# Single-ELL planning (the legacy PR-4 packing, kept as the cost model's
# skew baseline): grow k (powers of two) until the COO remainder holds at
# most this fraction of the incidences, then stop at the cap.
ELL_REMAINDER_FRACTION = 0.25
ELL_K_CAP = 64
# Degree-class planning: at most this many classes, widths capped here
# (a power-of-two width at most doubles a row's slots, and the DP only
# widens a class when few rows pay for it, so the cap merely bounds the
# absolute width of a single mega-hub row before it spills).
MAX_CLASSES = 4
CLASS_K_CAP = 65536
# One residual incidence costs a lane of the sorted segment reduce —
# serialized scatter work — vs a dense vectorized ELL slot.  Measured
# on the bench_delivery regimes (CPU XLA): the dense axis reduce moves
# ~125M slots/s vs ~11M lanes/s through the sorted scatter, so the DP
# prices a residual lane at ~12 dense slots and keeps hubs dense.
RESIDUAL_WEIGHT = 12.0
# Remainder / padded-row buckets: pow2 with a small floor, mirroring
# ``repro.core.serving.bucket_dim`` so serving signatures stay bounded.
_PAD_FLOOR = 8
_ROW_FLOOR = 8


def _pow2_at_least(n: int, floor: int = 1) -> int:
    b = max(int(floor), 1)
    while b < n:
        b *= 2
    return b


def _width_stats(degrees: np.ndarray, k_cap: int):
    """Per-candidate-width overflow stats from ONE cumulative histogram.

    Candidate widths are ``1, 2, 4, ..., min(pow2 >= max_degree, k_cap)``.
    Returns ``(widths, cnt_le, overflow, n_pos)`` where ``cnt_le[j]`` is
    the number of destinations with ``1 <= degree <= widths[j]`` and
    ``overflow[j] = sum(max(degree - widths[j], 0))`` — O(max_degree)
    total instead of rescanning the full degree array per width.
    """
    degrees = np.asarray(degrees, np.int64)
    pos = degrees[degrees > 0]
    n_pos = int(pos.size)
    if n_pos == 0:
        return (np.array([1], np.int64), np.zeros(1, np.int64),
                np.zeros(1, np.int64), 0)
    max_deg = int(pos.max())
    total = int(pos.sum())
    top = min(_pow2_at_least(max_deg), int(k_cap))
    widths = np.asarray(
        [1 << e for e in range(top.bit_length())], np.int64
    )
    hist = np.bincount(pos)
    cnt_cum = np.cumsum(hist)
    deg_cum = np.cumsum(hist * np.arange(hist.size, dtype=np.int64))
    idx = np.minimum(widths, max_deg)
    cnt_le = cnt_cum[idx]
    sum_le = deg_cum[idx]
    overflow = (total - sum_le) - widths * (n_pos - cnt_le)
    return widths, cnt_le, overflow, n_pos


def plan_ell_width(degrees: np.ndarray, nnz: int) -> tuple[int, int]:
    """Pick a SINGLE ELL width ``k`` for a degree distribution.

    Returns ``(k, remainder)``: the smallest power-of-two ``k`` (capped
    at ``ELL_K_CAP``) whose overflow — incidences past each
    destination's first ``k`` — is at most ``ELL_REMAINDER_FRACTION`` of
    ``nnz``, plus the overflow count at that ``k``.  This is the PR-4
    single-class packing, kept as the skew baseline the degree-class
    cost model compares against.  Vectorized over one cumulative degree
    histogram (``_width_stats``); deterministic in the histogram, so the
    Engine's cost model and the layout builder can never disagree.
    """
    if nnz <= 0 or np.asarray(degrees).size == 0:
        return 1, 0
    widths, _, overflow, n_pos = _width_stats(degrees, ELL_K_CAP)
    if n_pos == 0:
        return 1, 0
    ok = overflow <= ELL_REMAINDER_FRACTION * nnz
    ok[-1] = True  # the cap (or a width >= max degree) always stops
    j = int(np.argmax(ok))
    return int(widths[j]), int(overflow[j])


@dataclasses.dataclass(frozen=True)
class ClassPlan:
    """A degree-class partition: the data-dependent half of a layout.

    ``widths`` are ascending power-of-two ELL widths, one per class; a
    destination with live degree ``g > 0`` belongs to the first class
    with ``g <= k_c`` (hubs past the last width stay in the last class,
    spilling ``g - k_C`` incidences to the residual).  ``rows`` counts
    the destinations per class under the histogram the plan was built
    from; ``residual`` their total spill.  Pure data — hashable,
    comparable, derived deterministically from the degree histogram.
    """

    widths: tuple[int, ...]
    rows: tuple[int, ...]
    residual: int

    @property
    def n_classes(self) -> int:
        return len(self.widths)

    @property
    def padded_rows(self) -> int:
        """Dense ELL slots the plan commits to (pre row-padding)."""
        return int(sum(r * k for r, k in zip(self.rows, self.widths)))

    @property
    def work(self) -> int:
        """Total lanes the XLA lowering touches: dense slots + residual."""
        return self.padded_rows + int(self.residual)

    @property
    def built_rows(self) -> tuple:
        """Per-class row counts as ``build_delivery_layout`` will pad
        them (pow2, floor ``_ROW_FLOOR``) — what the tables really
        allocate."""
        return tuple(
            _pow2_at_least(max(int(r), 1), _ROW_FLOOR) for r in self.rows
        )

    @property
    def built_work(self) -> int:
        """Dense slots + residual at the BUILDER's row padding — the
        work a layout built from this plan actually executes (the cost
        model budgets on this, not the tighter DP-count ``work``)."""
        dense = sum(r * k for r, k in zip(self.built_rows, self.widths))
        return int(dense) + int(self.residual)

    @property
    def weighted_work(self) -> float:
        """The DP's objective: dense slots plus residual at
        ``RESIDUAL_WEIGHT`` (a residual lane pays the serialized sorted
        segment reduce; a dense slot is vectorized).  The cost model's
        skew detector compares plans on this scale."""
        return self.padded_rows + RESIDUAL_WEIGHT * self.residual


def plan_degree_classes(
    degrees: np.ndarray,
    nnz: int,
    *,
    max_classes: int = MAX_CLASSES,
    k_cap: int = CLASS_K_CAP,
) -> ClassPlan:
    """Partition a live-degree histogram into 1–``max_classes`` degree
    classes with power-of-two ELL widths.

    Dynamic programming over the candidate widths of ``_width_stats``:
    a class covering degrees ``(k_prev, k]`` costs ``count * k`` dense
    slots; hubs past the last width cost its width dense plus
    ``RESIDUAL_WEIGHT`` per spilled incidence (residual lanes take the
    serialized sorted segment reduce).  With <= 13 candidate widths and
    <= 4 classes the sweep is trivially cheap, and — like
    ``plan_ell_width`` — a pure function of the histogram.
    """
    degrees = np.asarray(degrees)
    if nnz <= 0 or degrees.size == 0 or not (degrees > 0).any():
        return ClassPlan(widths=(1,), rows=(0,), residual=0)
    widths, cnt_le, overflow, n_pos = _width_stats(degrees, k_cap)
    nw = len(widths)
    max_classes = max(int(max_classes), 1)

    INF = float("inf")
    # best[c][j]: min dense slots covering all degrees <= widths[j] with
    # c classes, the last of width widths[j].
    best = np.full((max_classes + 1, nw), INF)
    prev = np.full((max_classes + 1, nw), -1, np.int64)
    best[1, :] = cnt_le * widths
    for c in range(2, max_classes + 1):
        for j in range(c - 1, nw):
            cand = best[c - 1, :j] + (cnt_le[j] - cnt_le[:j]) * widths[j]
            jp = int(np.argmin(cand))
            if cand[jp] < best[c, j]:
                best[c, j] = cand[jp]
                prev[c, j] = jp
    # Close each (c, j) plan: hubs past widths[j] pay widths[j] dense
    # slots each plus weighted residual spill.
    hub_rows = n_pos - cnt_le
    close = hub_rows * widths + RESIDUAL_WEIGHT * overflow
    best_cost, best_c, best_j = INF, 1, nw - 1
    for c in range(1, max_classes + 1):
        for j in range(nw):
            cost = best[c, j] + close[j]
            if cost < best_cost:  # ties: fewer classes, smaller widths
                best_cost, best_c, best_j = cost, c, j
    chain = [best_j]
    for c in range(best_c, 1, -1):
        chain.append(int(prev[c, chain[-1]]))
    chain.reverse()
    plan_widths = [int(widths[j]) for j in chain]

    # Row counts per class; drop classes that own no destinations (the
    # DP can only produce them as no-cost ties).
    bounds = [0] + [cnt_le[j] for j in chain]
    rows = [int(bounds[i + 1] - bounds[i]) for i in range(len(chain))]
    rows[-1] += int(hub_rows[chain[-1]])
    keep = [i for i, r in enumerate(rows) if r > 0]
    if not keep:
        keep = [len(rows) - 1]
    return ClassPlan(
        widths=tuple(plan_widths[i] for i in keep),
        rows=tuple(rows[i] for i in keep),
        residual=int(overflow[chain[-1]]),
    )


def classify_degrees(degrees: np.ndarray, widths) -> np.ndarray:
    """Class index per destination under a plan's widths (-1 for
    zero-degree destinations, which own no slot).  Shared by the layout
    builder and the shard harmonizer so assignments always agree."""
    degrees = np.asarray(degrees, np.int64)
    w = np.asarray(widths, np.int64)
    cls = np.minimum(
        np.searchsorted(w, degrees, side="left"), len(w) - 1
    )
    return np.where(degrees > 0, cls, -1).astype(np.int64)


def class_block_e(k: int, block_e: int) -> int:
    """Class-local Pallas edge-block width: at least the caller's
    ``block_e``, grown toward the class's ELL width so hub classes
    amortize grid steps, capped at 1024.

    NOTE the cap is width-blind: for min/max/prod the kernel's
    ``[block_n, block_e, D]`` select-reduce tile scales with the
    message width ``D``, so on a REAL TPU a grown hub-class block with
    wide rows can exceed VMEM (interpret-mode CI cannot catch this) —
    part of the open TPU-validation item in ROADMAP.md; a D-aware cap
    needs measured VMEM budgets."""
    return min(max(int(block_e), _pow2_at_least(int(k))), 1024)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeliveryLayout:
    """One direction's precomputed fused-delivery layout (degree-classed).

    Array children (device arrays; leading dims may gain a partition dim
    under the distributed executor).  Per degree class ``c`` (tuples of
    length ``n_classes``):

      class_ell[c]: ``[rows_c, k_c]`` int32 — the class's destinations'
        first-``k_c`` sender ids, one row per destination slot (identity
        row ``n_src`` in empty slots).  The XLA lowering's dense table.
      class_src[c] / class_dst[c]: ``[nnz_c_pad]`` int32 — ALL the
        class's live incidences in dst-sorted order: sender id and
        class-LOCAL destination row (padding lanes: identity sender,
        out-of-range row).  The Pallas kernel's CSR form — no width cap,
        so the Pallas path needs no residual.
      class_bounds[c]: ``[n_tiles_c, 2]`` int32 — per output tile of
        ``block_n`` rows: (first edge block, n edge blocks) at
        ``class_block_e[c]`` granularity (the block-sparse skip).

    Shared children:

      inv_perm: ``[n_dst]`` int32 — destination id -> slot in the
        concatenated per-class outputs; zero-degree destinations point
        at the appended identity slot ``sum(class_rows)``.  Assembly is
        one gather — no scatter.
      rem_src / rem_dst: ``[rem_pad]`` int32 — hub incidences past the
        last class width, in dst-sorted COO (padding lanes: identity
        sender -> last destination).  XLA lowering only; statically
        skipped when ``rem_nnz == 0``.

    Static aux: ``n_src``, ``n_dst``, ``nnz`` (real incidences),
    ``rem_nnz`` (real residual), ``class_widths``, ``class_rows``
    (padded row counts — the array dims), ``block_n``,
    ``class_block_e``, ``class_max_blocks`` (per-class grid extents).
    """

    class_ell: tuple
    class_src: tuple
    class_dst: tuple
    class_bounds: tuple
    inv_perm: jnp.ndarray
    rem_src: jnp.ndarray
    rem_dst: jnp.ndarray
    n_src: int
    n_dst: int
    nnz: int
    rem_nnz: int
    class_widths: tuple
    class_rows: tuple
    block_n: int
    class_block_e: tuple
    class_max_blocks: tuple

    def tree_flatten(self):
        children = (
            self.class_ell, self.class_src, self.class_dst,
            self.class_bounds, self.inv_perm, self.rem_src, self.rem_dst,
        )
        aux = (
            self.n_src, self.n_dst, self.nnz, self.rem_nnz,
            self.class_widths, self.class_rows, self.block_n,
            self.class_block_e, self.class_max_blocks,
        )
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_classes(self) -> int:
        return len(self.class_widths)

    @property
    def n_slots(self) -> int:
        """Concatenated per-class output rows (the identity slot sits
        one past the end)."""
        return int(sum(self.class_rows))

    @property
    def k(self) -> int:
        """Widest class width (hub class)."""
        return int(max(self.class_widths))

    @property
    def ell_slots(self) -> int:
        """Total dense ELL slots across classes (padding-work metric)."""
        return int(
            sum(r * k for r, k in zip(self.class_rows, self.class_widths))
        )

    @property
    def rem_len(self) -> int:
        return int(self.rem_src.shape[-1])

    def shape_signature(self) -> tuple:
        """Hashable shape tuple for the serving executable cache key —
        covers every class-plan-dependent dim, so a degree-regime shift
        within a shape bucket legitimately recompiles."""
        return (
            tuple(tuple(a.shape) for a in self.class_ell),
            tuple(tuple(a.shape) for a in self.class_src),
            tuple(tuple(a.shape) for a in self.class_bounds),
            tuple(self.inv_perm.shape),
            tuple(self.rem_src.shape),
            self.class_widths, self.class_rows, self.class_block_e,
            self.class_max_blocks, self.rem_nnz,
            self.n_src, self.n_dst, self.nnz,
        )


def tile_block_bounds(
    row_offsets: np.ndarray, n_dst_pad: int, block_n: int, block_e: int
) -> tuple[np.ndarray, int]:
    """Per-output-tile edge-block ranges from CSR row offsets.

    Tile ``i`` covers destinations ``[i*block_n, (i+1)*block_n)``; its
    incident edges are CSR rows ``[row_offsets[lo], row_offsets[hi])``,
    which span edge blocks ``[floor(lo_e/block_e), ceil(hi_e/block_e))``.
    Boundary blocks contain neighbors' edges; the kernel masks them by
    destination.  Returns ``([n_tiles, 2] (start, count), max_count)``.
    """
    n_tiles = n_dst_pad // block_n
    bounds = np.zeros((n_tiles, 2), np.int32)
    n_real = len(row_offsets) - 1
    for i in range(n_tiles):
        lo = row_offsets[min(i * block_n, n_real)]
        hi = row_offsets[min((i + 1) * block_n, n_real)]
        b_lo = lo // block_e
        b_hi = -(-hi // block_e)
        bounds[i] = (b_lo, max(b_hi - b_lo, 0))
    max_blocks = int(bounds[:, 1].max()) if n_tiles else 0
    return bounds, max(max_blocks, 1)


def build_delivery_layout(
    src,
    dst,
    e_mask,
    n_src: int,
    n_dst: int,
    *,
    plan: ClassPlan | None = None,
    block_n: int = 128,
    block_e: int = 256,
    class_rows_pad: tuple | None = None,
    class_nnz_pad: tuple | None = None,
    rem_pad_to: int | None = None,
) -> DeliveryLayout:
    """Build one direction's degree-class layout from a concrete
    incidence list.

    ``src``/``dst``/``e_mask`` are host-transferable arrays (``e_mask``
    may be None).  ``plan=None`` lets ``plan_degree_classes`` pick the
    class boundaries and widths from the live-degree histogram; the
    distributed builder passes a shared plan so shard layouts agree.
    ``class_rows_pad`` / ``class_nnz_pad`` / ``rem_pad_to`` force the
    per-class row counts, edge-array lengths and residual pad (each >=
    what this shard needs) so per-shard layouts stack into one
    shard_map operand.
    """
    t_build0 = time.perf_counter()
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    nnz = len(src)
    live = (
        np.asarray(e_mask) != 0
        if e_mask is not None
        else np.ones(nnz, bool)
    )

    live_deg = (
        np.bincount(dst[live], minlength=max(n_dst, 1))[:n_dst]
        if nnz
        else np.zeros(max(n_dst, 1), np.int64)[:n_dst]
    )
    n_live = int(live.sum())
    if plan is None:
        plan = plan_degree_classes(live_deg, n_live)
    widths = np.asarray(plan.widths, np.int64)
    n_classes = len(widths)

    cls = classify_degrees(live_deg, widths)
    rows_real = np.bincount(
        cls[cls >= 0], minlength=n_classes
    )[:n_classes]
    if class_rows_pad is None:
        rows_pad = tuple(
            _pow2_at_least(max(int(r), 1), _ROW_FLOOR) for r in rows_real
        )
    else:
        rows_pad = tuple(int(r) for r in class_rows_pad)
        assert all(p >= r for p, r in zip(rows_pad, rows_real)), (
            rows_pad, rows_real,
        )

    # Slot assignment: class-major, ascending destination id within a
    # class; zero-degree destinations share the appended identity slot.
    base = np.concatenate([[0], np.cumsum(rows_pad)]).astype(np.int64)
    n_slots = int(base[-1])
    inv_perm = np.full(n_dst, n_slots, np.int64)
    class_members = []
    for c in range(n_classes):
        members = np.flatnonzero(cls == c)
        class_members.append(members)
        inv_perm[members] = base[c] + np.arange(len(members))

    # One dst-sorted scan feeds every packing.  Stability keeps each
    # segment's rows in original incidence order, so reduction order —
    # and therefore bitwise results for order-sensitive exact sums —
    # matches the reference scatter path.
    order = np.argsort(dst, kind="stable")
    s_src = src[order].astype(np.int32)
    s_dst = dst[order]
    s_live = live[order]
    if nnz:
        counts = np.bincount(s_dst, minlength=max(n_dst, 1))
        seg_starts = np.zeros(counts.size + 1, np.int64)
        np.cumsum(counts, out=seg_starts[1:])
        live_cum = np.cumsum(s_live)
        live_before = np.concatenate([[0], live_cum])[seg_starts[s_dst]]
        live_rank = live_cum - 1 - live_before  # valid on live lanes
        lane_cls = cls[s_dst]
        lane_k = widths[np.maximum(lane_cls, 0)]
        in_ell = s_live & (live_rank < lane_k)
        over = s_live & (live_rank >= lane_k)
    else:
        lane_cls = np.zeros(0, np.int64)
        live_rank = np.zeros(0, np.int64)
        in_ell = over = np.zeros(0, bool)

    # Per-class ELL tables (XLA lowering).
    class_ell = []
    for c in range(n_classes):
        tbl = np.full((rows_pad[c], int(widths[c])), n_src, np.int32)
        sel = in_ell & (lane_cls == c)
        if sel.any():
            r_local = inv_perm[s_dst[sel]] - base[c]
            tbl[r_local, live_rank[sel]] = s_src[sel]
        class_ell.append(tbl)

    # Residual COO (dst-sorted: the scan order preserves it).  Padding
    # lanes keep rem_dst sorted by pointing at the last destination with
    # an identity sender (contributes nothing).
    rem_s = s_src[over]
    rem_d = s_dst[over]
    rem_nnz = len(rem_s)
    if rem_pad_to is not None:
        assert rem_pad_to >= rem_nnz, (rem_pad_to, rem_nnz)
        rem_pad = int(rem_pad_to)
    else:
        rem_pad = _pow2_at_least(max(rem_nnz, 1), _PAD_FLOOR)
    rem_src = np.full(rem_pad, n_src, np.int32)
    rem_dst = np.full(rem_pad, max(n_dst - 1, 0), np.int32)
    rem_src[:rem_nnz] = rem_s
    rem_dst[:rem_nnz] = rem_d

    # Per-class dst-sorted CSR edge arrays (Pallas lowering): every live
    # incidence of the class — hub tails included, the CSR form has no
    # width cap.  Padding lanes: identity sender, out-of-range row.
    class_src_a, class_dst_a, class_bounds, c_block_e, c_max_blocks = (
        [], [], [], [], [],
    )
    for c in range(n_classes):
        be = class_block_e(int(widths[c]), block_e)
        sel = s_live & (lane_cls == c) if nnz else np.zeros(0, bool)
        e_src = s_src[sel]
        e_dst_local = (inv_perm[s_dst[sel]] - base[c]).astype(np.int32)
        nnz_c = len(e_src)
        rows_blk = -(-rows_pad[c] // block_n) * block_n
        want = nnz_c if class_nnz_pad is None else int(class_nnz_pad[c])
        assert want >= nnz_c, (want, nnz_c)
        nnz_c_pad = -(-max(want, 1) // be) * be
        a_src = np.full(nnz_c_pad, n_src, np.int32)
        a_dst = np.full(nnz_c_pad, rows_blk, np.int32)
        a_src[:nnz_c] = e_src
        a_dst[:nnz_c] = e_dst_local
        row_counts = np.zeros(rows_pad[c], np.int64)
        members = class_members[c]
        row_counts[: len(members)] = live_deg[members]
        offsets = np.zeros(rows_pad[c] + 1, np.int64)
        np.cumsum(row_counts, out=offsets[1:])
        bounds, mb = tile_block_bounds(offsets, rows_blk, block_n, be)
        class_src_a.append(a_src)
        class_dst_a.append(a_dst)
        class_bounds.append(bounds)
        c_block_e.append(be)
        c_max_blocks.append(mb)

    layout = DeliveryLayout(
        class_ell=tuple(jnp.asarray(t) for t in class_ell),
        class_src=tuple(jnp.asarray(a) for a in class_src_a),
        class_dst=tuple(jnp.asarray(a) for a in class_dst_a),
        class_bounds=tuple(jnp.asarray(b) for b in class_bounds),
        inv_perm=jnp.asarray(inv_perm, jnp.int32),
        rem_src=jnp.asarray(rem_src),
        rem_dst=jnp.asarray(rem_dst),
        n_src=int(n_src),
        n_dst=int(n_dst),
        nnz=int(nnz),
        rem_nnz=int(rem_nnz),
        class_widths=tuple(int(w) for w in widths),
        class_rows=tuple(int(r) for r in rows_pad),
        block_n=int(block_n),
        class_block_e=tuple(c_block_e),
        class_max_blocks=tuple(c_max_blocks),
    )
    reg = default_registry()
    reg.counter("delivery.layouts_built").inc()
    reg.counter("delivery.ell_slots").inc(layout.ell_slots)
    reg.counter("delivery.residual_lanes").inc(layout.rem_len)
    reg.histogram("delivery.build_s").record(
        time.perf_counter() - t_build0
    )
    return layout


def layout_pair(
    hg_src, hg_dst, e_mask, n_vertices: int, n_hyperedges: int, **kw
) -> tuple[DeliveryLayout, DeliveryLayout]:
    """Both half-superstep directions for one incidence list:
    vertex->hyperedge (combine by ``dst``) and hyperedge->vertex
    (combine by ``src``)."""
    fwd = build_delivery_layout(
        hg_src, hg_dst, e_mask, n_vertices, n_hyperedges, **kw
    )
    bwd = build_delivery_layout(
        hg_dst, hg_src, e_mask, n_hyperedges, n_vertices, **kw
    )
    return fwd, bwd


Pytree = Any
