"""The fused delivery data path expressed to XLA (sliced-ELL + sorted COO).

Same algorithm as the Pallas class kernels in ``fused`` — mask folded
into the layout, message rows read once, combine without a serialized
scatter — but lowered through stock XLA ops for hosts without a native
Pallas backend (CPU CI, GPU until a Triton port lands):

* each degree class's incidences sit in its own dense ``[rows_c, k_c]``
  id table: one vectorized gather and one dense axis reduction per
  class replace the scatter (XLA's CPU scatter-add serializes; a
  ``[rows_c, k_c, D]`` reduce vectorizes).  Class widths track the
  degree histogram, so hubs stay dense and the tail stays narrow;
* the per-class partials concatenate (plus one identity row for
  zero-degree destinations) and assemble with ONE gather through the
  layout's ``inv_perm`` — no scatter anywhere on the dense path;
* hub incidences past the last class width take a segment reduce over
  *dst-sorted* ids (``indices_are_sorted=True``) and merge in with one
  ``combine`` — statically skipped when the layout has no residual.

Statically-dead lanes were dropped at layout-build time, so only a
dynamic ``active`` vector costs a mask here — and it is a per-class
``[rows_c, k_c]`` byte mask, not an ``[nnz, D]`` float ``where``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.deliver.layout import DeliveryLayout
from repro.sparse.segment import Monoid

_AXIS_REDUCE = {
    "sum": jnp.sum,
    "min": jnp.min,
    "max": jnp.max,
    "prod": jnp.prod,
}


def _reduce_axis1(x: jnp.ndarray, monoid: Monoid) -> jnp.ndarray:
    if monoid.name == "or":
        return jnp.any(x, axis=1)
    return _AXIS_REDUCE[monoid.name](x, axis=1)


def deliver_ell_leaf(
    msgs: jnp.ndarray,
    layout: DeliveryLayout,
    monoid: Monoid,
    active: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """One leaf's fused delivery: ``[n_src, ...] -> [n_dst, ...]``."""
    ident = monoid.identity(msgs.dtype)
    ident_row = jnp.full((1,) + msgs.shape[1:], ident, msgs.dtype)
    msgs_aug = jnp.concatenate([msgs, ident_row], axis=0)

    act_aug = None
    if active is not None:
        act_aug = jnp.concatenate(
            [active.astype(bool), jnp.ones((1,), bool)]
        )

    trail = (1,) * (msgs.ndim - 1)

    outs = []
    for ell in layout.class_ell:
        rows_c, k = ell.shape
        rows = jnp.take(
            msgs_aug, ell.reshape(-1), axis=0
        ).reshape((rows_c, k) + msgs.shape[1:])
        if act_aug is not None:
            live = jnp.take(act_aug, ell, axis=0)  # [rows_c, k]
            rows = jnp.where(live.reshape((rows_c, k) + trail), rows, ident)
        outs.append(_reduce_axis1(rows, monoid))
    # Assembly is a pure gather: slot order is class-major, and the
    # appended identity row serves every zero-degree destination.
    out = jnp.take(
        jnp.concatenate(outs + [ident_row], axis=0),
        layout.inv_perm, axis=0,
    )

    if layout.rem_nnz == 0:
        return out
    rem_rows = jnp.take(msgs_aug, layout.rem_src, axis=0)
    if act_aug is not None:
        rem_live = jnp.take(act_aug, layout.rem_src, axis=0)
        rem_rows = jnp.where(
            rem_live.reshape((-1,) + trail), rem_rows, ident
        )
    overflow = monoid.segment(
        rem_rows, layout.rem_dst, num_segments=layout.n_dst,
        indices_are_sorted=True,
    )
    return monoid.combine(out, overflow)
