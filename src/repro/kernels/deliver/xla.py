"""The fused delivery data path expressed to XLA (ELL + sorted COO).

Same algorithm as ``fused.deliver_fused_pallas`` — mask folded into the
layout, message rows read once, combine without a serialized scatter —
but lowered through stock XLA ops for hosts without a native Pallas
backend (CPU CI, GPU until a Triton port lands):

* the first ``k`` incidences of every destination sit in the layout's
  dense ``[n_dst, k]`` id table: one vectorized gather and one dense
  axis reduction replace the scatter (XLA's CPU scatter-add serializes;
  a ``[n_dst, k, D]`` reduce vectorizes);
* overflow incidences of heavy destinations take a segment reduce over
  *dst-sorted* ids (``indices_are_sorted=True``) and merge in with one
  ``combine``.

Statically-dead lanes were redirected to the appended identity row at
layout-build time, so only a dynamic ``active`` vector costs a mask
here — and it is a ``[n, k]`` byte mask, not an ``[nnz, D]`` float
``where``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.deliver.layout import DeliveryLayout
from repro.sparse.segment import Monoid

_AXIS_REDUCE = {
    "sum": jnp.sum,
    "min": jnp.min,
    "max": jnp.max,
    "prod": jnp.prod,
}


def _reduce_axis1(x: jnp.ndarray, monoid: Monoid) -> jnp.ndarray:
    if monoid.name == "or":
        return jnp.any(x, axis=1)
    return _AXIS_REDUCE[monoid.name](x, axis=1)


def deliver_ell_leaf(
    msgs: jnp.ndarray,
    layout: DeliveryLayout,
    monoid: Monoid,
    active: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """One leaf's fused delivery: ``[n_src, ...] -> [n_dst, ...]``."""
    ident = monoid.identity(msgs.dtype)
    ident_row = jnp.full((1,) + msgs.shape[1:], ident, msgs.dtype)
    msgs_aug = jnp.concatenate([msgs, ident_row], axis=0)

    act_aug = None
    if active is not None:
        act_aug = jnp.concatenate(
            [active.astype(bool), jnp.ones((1,), bool)]
        )

    n_dst, k = layout.ell_idx.shape
    trail = (1,) * (msgs.ndim - 1)

    rows = jnp.take(
        msgs_aug, layout.ell_idx.reshape(-1), axis=0
    ).reshape((n_dst, k) + msgs.shape[1:])
    if act_aug is not None:
        live = jnp.take(act_aug, layout.ell_idx, axis=0)  # [n_dst, k]
        rows = jnp.where(live.reshape((n_dst, k) + trail), rows, ident)
    out = _reduce_axis1(rows, monoid)

    rem_rows = jnp.take(msgs_aug, layout.rem_src, axis=0)
    if act_aug is not None:
        rem_live = jnp.take(act_aug, layout.rem_src, axis=0)
        rem_rows = jnp.where(
            rem_live.reshape((-1,) + trail), rem_rows, ident
        )
    overflow = monoid.segment(
        rem_rows, layout.rem_dst, num_segments=n_dst,
        indices_are_sorted=True,
    )
    return monoid.combine(out, overflow)
