"""Pallas TPU kernels for the framework's compute hot spots.

deliver/ - fused incidence delivery: scalar-prefetch gather + mask +
           segment-combine over a dst-sorted CSR layout (the whole
           half-superstep data path; the ``delivery='pallas_fused'``
           design point), with an equivalent ELL+COO XLA lowering for
           hosts without a native Pallas backend.
segsum/  - segment-sum as blocked one-hot matmul on the MXU (the MESH
           combine step: scatter-reduce -> dense systolic work);
           unsorted-fallback reference for the fused deliver kernel.
isect/   - hyperedge-pair bitset intersection (AND+popcount), with an
           in-kernel scalar-prefetch row gather.
flash/   - FlashAttention forward (prefill hot spot).

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with the interpret switch), ref.py (pure-jnp oracle).  Kernels are
an opt-in fast path; the jnp reference is the default execution path and
the oracle every sweep asserts against.
"""
