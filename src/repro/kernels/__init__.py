"""Pallas TPU kernels for the framework's compute hot spots.

segsum/  - segment-sum as blocked one-hot matmul on the MXU (the MESH
           combine step: scatter-reduce -> dense systolic work).
flash/   - FlashAttention forward (prefill hot spot).

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with the interpret switch), ref.py (pure-jnp oracle).  Kernels are
an opt-in fast path; the jnp reference is the default execution path and
the oracle every sweep asserts against.
"""
