"""jit'd public wrapper for the segsum MXU kernel (handles padding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segsum.segsum import segsum_pallas


def segment_sum_mxu(
    msgs: jnp.ndarray,
    dst: jnp.ndarray,
    num_segments: int,
    *,
    sorted_dst: bool = False,
    block_n: int = 128,
    block_e: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Drop-in for ``jax.ops.segment_sum(msgs, dst, num_segments)`` running
    the blocked one-hot MXU kernel.  Pads E to a block multiple (padding
    edges point past every output tile).

    ``sorted_dst=True`` asserts ``dst`` is non-decreasing (a
    ``HyperGraph.sorted_by_dst`` product) and routes through the
    block-sparse skip: per-tile CSR block bounds are computed host-side
    (``dst`` must be concrete) so each output tile reads only its
    incident edge blocks instead of the unsorted fallback's full
    j-sweep.
    """
    e, d = msgs.shape
    e_pad = -(-e // block_e) * block_e
    n_pad = -(-num_segments // block_n) * block_n
    tile_bounds = None
    max_blocks = None
    if sorted_dst and e:
        from repro.kernels.deliver import tile_block_bounds

        dst_host = np.asarray(dst)
        assert (np.diff(dst_host) >= 0).all(), (
            "sorted_dst=True needs non-decreasing dst ids (see "
            "HyperGraph.sorted_by_dst)"
        )
        counts = np.bincount(
            dst_host, minlength=max(num_segments, 1)
        )[: max(num_segments, 1)]
        offsets = np.zeros(max(num_segments, 1) + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        bounds, max_blocks = tile_block_bounds(
            offsets, n_pad, block_n, block_e
        )
        tile_bounds = jnp.asarray(bounds)
    if e_pad != e:
        msgs = jnp.concatenate(
            [msgs, jnp.zeros((e_pad - e, d), msgs.dtype)], axis=0
        )
        dst = jnp.concatenate(
            [dst, jnp.full((e_pad - e,), n_pad, dst.dtype)], axis=0
        )
    out = segsum_pallas(
        msgs, dst, num_segments, tile_bounds, max_blocks,
        block_n=block_n, block_e=block_e, interpret=interpret,
    )
    return out.astype(msgs.dtype)
