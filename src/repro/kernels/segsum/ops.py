"""jit'd public wrapper for the segsum MXU kernel (handles padding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segsum.segsum import segsum_pallas


def segment_sum_mxu(
    msgs: jnp.ndarray,
    dst: jnp.ndarray,
    num_segments: int,
    *,
    block_n: int = 128,
    block_e: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Drop-in for ``jax.ops.segment_sum(msgs, dst, num_segments)`` running
    the blocked one-hot MXU kernel.  Pads E to a block multiple (padding
    edges point past every output tile)."""
    e, d = msgs.shape
    e_pad = -(-e // block_e) * block_e
    n_pad = -(-num_segments // block_n) * block_n
    if e_pad != e:
        msgs = jnp.concatenate(
            [msgs, jnp.zeros((e_pad - e, d), msgs.dtype)], axis=0
        )
        dst = jnp.concatenate(
            [dst, jnp.full((e_pad - e,), n_pad, dst.dtype)], axis=0
        )
    out = segsum_pallas(
        msgs, dst, num_segments,
        block_n=block_n, block_e=block_e, interpret=interpret,
    )
    return out.astype(msgs.dtype)
