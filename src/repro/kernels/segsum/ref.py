"""Pure-jnp oracle for the segsum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_ref(
    msgs: jnp.ndarray, dst: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    """msgs [E, D] scattered-summed by dst [E] into [N, D]."""
    return jax.ops.segment_sum(msgs, dst, num_segments=num_segments)
