"""Segment-sum as a blocked one-hot matmul on the MXU.

The MESH combine step (deliver: scatter-reduce messages by destination) is
an irregular scatter on GPUs; on TPU the winning shape is dense systolic
work.  Per grid step (i, j):

    out[i*BN:(i+1)*BN, :] += onehot(dst_block_j)[BN, BE] @ msg_block_j[BE, D]

The one-hot is built in VMEM with ``broadcasted_iota`` + compare (no
gather/scatter at all); the contraction runs on the MXU with fp32
accumulation.  Grid dim j is the reduction dimension: the out BlockSpec
maps both j's to the same tile, initialized at j==0 (standard Pallas
revisiting-accumulator pattern).

Tiling: BE x D msg block and BN x D out tile must fit VMEM; BN/BE chosen
as multiples of the 128-lane MXU edge.

Two grid regimes:

* **unsorted fallback (reference)**: every output tile sweeps every
  edge block — O(n_tiles * n_blocks) grid steps regardless of where a
  tile's edges actually live.  Correct for any ``dst`` order; this is
  the oracle form.
* **sorted + block-sparse skip**: for dst-sorted inputs, a
  scalar-prefetched ``[n_tiles, 2]`` bounds table (CSR row offsets at
  ``block_e`` granularity — ``repro.kernels.deliver.tile_block_bounds``,
  the same layout product the fused deliver kernel uses) restricts each
  tile to its incident edge blocks, so grid work scales with the tile's
  degree sum instead of nnz.

For the full fused half-superstep (gather + mask + combine in one
kernel) see ``repro.kernels.deliver`` — this kernel remains the
combine-only form fed by pre-gathered rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum_kernel(dst_ref, msg_ref, out_ref, *, block_n: int):
    i = pl.program_id(0)   # output row-tile index
    j = pl.program_id(1)   # edge-block index (reduction dim)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]                       # [BE] int32 (block of ids)
    msgs = msg_ref[...]                      # [BE, D]
    base = i * block_n
    # one-hot [BN, BE]: rows = local segment ids, cols = edges
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_n, dst.shape[0]), 0)
    onehot = (rows + base == dst[None, :]).astype(msgs.dtype)
    out_ref[...] += jax.lax.dot_general(
        onehot, msgs,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype,
    )


def _segsum_sorted_kernel(bounds_ref, dst_ref, msg_ref, out_ref,
                          *, block_n: int):
    i = pl.program_id(0)
    j = pl.program_id(1)   # LOCAL block index within tile i's range

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j < bounds_ref[i, 1])
    def _accumulate():
        dst = dst_ref[...]
        msgs = msg_ref[...]
        base = i * block_n
        rows = jax.lax.broadcasted_iota(
            jnp.int32, (block_n, dst.shape[0]), 0
        )
        onehot = (rows + base == dst[None, :]).astype(msgs.dtype)
        out_ref[...] += jax.lax.dot_general(
            onehot, msgs,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=out_ref.dtype,
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_segments", "max_blocks", "block_n", "block_e", "interpret",
    ),
)
def segsum_pallas(
    msgs: jnp.ndarray,
    dst: jnp.ndarray,
    num_segments: int,
    tile_bounds: jnp.ndarray | None = None,
    max_blocks: int | None = None,
    *,
    block_n: int = 128,
    block_e: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """msgs [E, D], dst [E] -> [num_segments, D] (f32 accumulate).

    E must be a multiple of block_e and num_segments of block_n (the ops.py
    wrapper pads; padding edges carry dst == num_segments_padded, which no
    output tile matches, so they contribute nothing).

    ``tile_bounds`` + ``max_blocks`` (from
    ``repro.kernels.deliver.tile_block_bounds`` over dst-SORTED input)
    enable the block-sparse skip; omitted, the kernel runs the unsorted
    fallback's full j-sweep.
    """
    e, d = msgs.shape
    assert e % block_e == 0, (e, block_e)
    n_pad = -(-num_segments // block_n) * block_n

    if tile_bounds is None:
        grid = (n_pad // block_n, e // block_e)
        out = pl.pallas_call(
            functools.partial(_segsum_kernel, block_n=block_n),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_e,), lambda i, j: (j,)),
                pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
            interpret=interpret,
        )(dst, msgs)
        return out[:num_segments]

    total_blocks = e // block_e
    n_tiles = n_pad // block_n
    assert tile_bounds.shape == (n_tiles, 2), (
        tile_bounds.shape, n_tiles,
    )

    def edge_map(i, j, b):
        safe = b[i, 0] + jnp.minimum(j, jnp.maximum(b[i, 1] - 1, 0))
        return (jnp.clip(safe, 0, total_blocks - 1),)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, max(int(max_blocks or 1), 1)),
        in_specs=[
            pl.BlockSpec((block_e,), edge_map),
            pl.BlockSpec((block_e, d), lambda i, j, b: (edge_map(i, j, b)[0], 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i, j, b: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_segsum_sorted_kernel, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(tile_bounds, dst, msgs)
    return out[:num_segments]
