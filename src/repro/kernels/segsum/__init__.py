from repro.kernels.segsum.ops import segment_sum_mxu

__all__ = ["segment_sum_mxu"]
