"""Pure-jnp oracle for the bitset intersection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pair_intersect_ref(
    bits: jnp.ndarray, ea: jnp.ndarray, eb: jnp.ndarray
) -> jnp.ndarray:
    """[E, W] uint32 bitsets, [P] pair ids -> [P] int32 sizes."""
    inter = jnp.take(bits, ea, axis=0) & jnp.take(bits, eb, axis=0)
    return jax.lax.population_count(inter).astype(jnp.int32).sum(axis=1)
