"""jit'd public wrapper for the bitset intersection kernel (padding +
row gather)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.isect.isect import isect_pallas


def pair_intersect_bitset(
    bits: jnp.ndarray,
    ea: jnp.ndarray,
    eb: jnp.ndarray,
    *,
    block_p: int = 512,
    block_w: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Intersection size per hyperedge pair over a packed bitset index.

    ``bits`` is the ``[E, W] uint32`` member bitset
    (``repro.motifs.intersect.build_index(hg, "bitset").data``); ``ea`` /
    ``eb`` are ``[P]`` hyperedge ids.  Rows are gathered host-of-kernel
    (XLA fuses the gather), the streaming AND+popcount runs in Pallas.
    """
    n = ea.shape[0]
    a = jnp.take(bits, ea, axis=0)
    b = jnp.take(bits, eb, axis=0)
    p_pad = -(-max(n, 1) // block_p) * block_p
    w = bits.shape[1]
    w_pad = -(-w // block_w) * block_w
    a = jnp.pad(a, ((0, p_pad - n), (0, w_pad - w)))
    b = jnp.pad(b, ((0, p_pad - n), (0, w_pad - w)))
    out = isect_pallas(
        a, b, block_p=block_p, block_w=block_w, interpret=interpret
    )
    return out[:n]
