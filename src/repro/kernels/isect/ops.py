"""jit'd public wrapper for the bitset intersection kernel (padding +
row gather)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.isect.isect import isect_pallas, isect_pallas_fused


def pair_intersect_bitset(
    bits: jnp.ndarray,
    ea: jnp.ndarray,
    eb: jnp.ndarray,
    *,
    block_p: int = 512,
    block_w: int = 8,
    fused: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """Intersection size per hyperedge pair over a packed bitset index.

    ``bits`` is the ``[E, W] uint32`` member bitset
    (``repro.motifs.intersect.build_index(hg, "bitset").data``); ``ea`` /
    ``eb`` are ``[P]`` hyperedge ids.

    ``fused=True`` (default): pair ids are scalar-prefetched and rows
    gathered *inside* the kernel per word tile — the ``[P, W]`` operand
    pair never materializes in HBM, which is the whole cost for skewed
    batches re-reading hot rows.  ``fused=False`` keeps the original
    host-of-kernel gather (XLA fuses the ``take``) as the reference
    form.
    """
    n = ea.shape[0]
    if n == 0 or bits.shape[0] == 0:
        return jnp.zeros((n,), jnp.int32)
    p_pad = -(-max(n, 1) // block_p) * block_p
    w = bits.shape[1]
    w_pad = -(-w // block_w) * block_w
    if fused:
        ea_p = jnp.zeros((p_pad,), jnp.int32).at[:n].set(
            ea.astype(jnp.int32)
        )
        eb_p = jnp.zeros((p_pad,), jnp.int32).at[:n].set(
            eb.astype(jnp.int32)
        )
        bits_p = jnp.pad(bits, ((0, 0), (0, w_pad - w)))
        out = isect_pallas_fused(
            bits_p, ea_p, eb_p,
            block_p=block_p, block_w=block_w, interpret=interpret,
        )
        return out[:n]
    a = jnp.take(bits, ea, axis=0)
    b = jnp.take(bits, eb, axis=0)
    a = jnp.pad(a, ((0, p_pad - n), (0, w_pad - w)))
    b = jnp.pad(b, ((0, p_pad - n), (0, w_pad - w)))
    out = isect_pallas(
        a, b, block_p=block_p, block_w=block_w, interpret=interpret
    )
    return out[:n]
