"""Hyperedge-pair bitset intersection as a blocked AND+popcount kernel.

The dense-bitset path of ``repro.motifs.intersect`` packs each
hyperedge's member set into uint32 lanes; an intersection size is then
``sum(popcount(a & b))`` over the word lanes — pure streaming VPU work
with no gather/scatter inside the hot loop (rows are pre-gathered by
the ops wrapper, exactly like the paper's clique expansion precomputes
pair overlaps).

Per grid step (i, j):

    out[i*BP:(i+1)*BP] += popcount(A_block & B_block).sum(axis=words)

Grid dim j is the reduction over word-lane tiles: the out BlockSpec maps
every j to the same pair tile, initialized at j == 0 (the standard
Pallas revisiting-accumulator pattern, same as the segsum kernel).

popcount is SWAR (shift/mask/multiply on uint32) rather than
``lax.population_count`` so the kernel stays portable across Pallas
backends that lack a popcount lowering.

Two entry points: ``isect_pallas`` consumes pre-gathered ``[P, W]`` row
pairs (the original form — the ops wrapper's outside-Pallas ``take``
materializes both operands in HBM); ``isect_pallas_fused`` takes the
packed ``[E, W]`` bitset plus scalar-prefetched pair ids and gathers
rows *inside* the kernel, so skewed pair batches re-reading the same hot
hyperedge rows never materialize the ``[P, W]`` operands at all — the
same fused-gather BlockSpec pattern as ``repro.kernels.deliver``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """SWAR population count per uint32 lane (wrapping arithmetic)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def _isect_kernel(a_ref, b_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    inter = a_ref[...] & b_ref[...]              # [BP, BW] uint32
    counts = _popcount_u32(inter).astype(jnp.int32)
    out_ref[...] += counts.sum(axis=1)


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_w", "interpret")
)
def isect_pallas(
    a_bits: jnp.ndarray,
    b_bits: jnp.ndarray,
    *,
    block_p: int = 512,
    block_w: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """a_bits/b_bits [P, W] uint32 -> [P] int32 intersection sizes.

    P must be a multiple of block_p and W of block_w (the ops.py wrapper
    pads; zero padding words AND to zero and contribute nothing).
    """
    p, w = a_bits.shape
    assert p % block_p == 0 and w % block_w == 0, (p, w, block_p, block_w)
    grid = (p // block_p, w // block_w)
    return pl.pallas_call(
        _isect_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((block_p, block_w), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.int32),
        interpret=interpret,
    )(a_bits, b_bits)


def _isect_fused_kernel(ea_ref, eb_ref, bits_ref, out_ref,
                        *, block_p: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Scalar-prefetched pair ids -> in-kernel row gather from the word
    # tile: the [P, W] operand pair never exists outside VMEM.
    ea = ea_ref[pl.ds(i * block_p, block_p)]
    eb = eb_ref[pl.ds(i * block_p, block_p)]
    bits = bits_ref[...]                          # [E, BW] word tile
    a = jnp.take(bits, ea, axis=0)                # [BP, BW]
    b = jnp.take(bits, eb, axis=0)
    counts = _popcount_u32(a & b).astype(jnp.int32)
    out_ref[...] += counts.sum(axis=1)


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_w", "interpret")
)
def isect_pallas_fused(
    bits: jnp.ndarray,
    ea: jnp.ndarray,
    eb: jnp.ndarray,
    *,
    block_p: int = 512,
    block_w: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """``bits [E, W] uint32``, ``ea``/``eb [P] int32`` -> ``[P]`` int32.

    The fused-gather variant: pair ids ride the scalar-prefetch channel
    (``pltpu.PrefetchScalarGridSpec``) and rows are gathered in-kernel
    per word tile, so a skewed pair batch hitting the same hot rows
    costs VMEM reads, not a ``[P, W]``-materializing HBM gather.  P must
    be a multiple of ``block_p`` and W of ``block_w`` (ops.py pads; id
    padding rows point at row 0 and are sliced off).
    """
    p = ea.shape[0]
    e, w = bits.shape
    assert p % block_p == 0 and w % block_w == 0, (p, w, block_p, block_w)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p // block_p, w // block_w),
        in_specs=[
            pl.BlockSpec((e, block_w), lambda i, j, ea, eb: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i, j, ea, eb: (i,)),
    )
    return pl.pallas_call(
        functools.partial(_isect_fused_kernel, block_p=block_p),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p,), jnp.int32),
        interpret=interpret,
    )(ea, eb, bits)
