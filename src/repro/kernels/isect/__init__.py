from repro.kernels.isect.ops import pair_intersect_bitset

__all__ = ["pair_intersect_bitset"]
