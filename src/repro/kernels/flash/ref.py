"""Pure-jnp oracle for the flash attention kernel (MHA, optional causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True
) -> jnp.ndarray:
    """q,k,v: [B, H, S, D] -> [B, H, S, D] (fp32 softmax)."""
    d = q.shape[-1]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(d))
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
