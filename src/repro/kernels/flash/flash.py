"""FlashAttention forward in Pallas (TPU BlockSpec tiling).

Grid: (B*H, Sq/BQ, Sk/BK) with the KV axis innermost (reduction).  Each
step streams one BK x D key/value tile through VMEM against a resident
BQ x D query tile, maintaining the running-max/denominator recurrence in
f32 VMEM scratch.  Causal tiles entirely above the diagonal are masked
(the index map cannot skip them without scalar prefetch — noted as the
block-sparse §Perf follow-up, same skip structure as segsum).

VMEM budget per step: BQ*D (q) + BK*D (k, v) + BQ*BK (scores) + BQ*D (acc)
— with BQ=BK=128, D<=256 comfortably under 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, block_q: int,
                  block_k: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                         # [BQ, D]
    k = k_ref[0]                         # [BK, D]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                            # [BQ, BK]
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0
        )
        kpos = jk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q,k,v [B, H, S, D] -> out [B, H, S, D]."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, sk, d)
    vf = v.reshape(bh, sk, d)
    grid = (bh, sq // block_q, sk // block_k)
    scale = float(1.0 / (d**0.5))

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal,
            block_q=block_q, block_k=block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            # f32 VMEM scratch: accumulator + running max + denominator
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
