"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash.flash import flash_pallas


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """MHA forward, [B, H, S, D] layout.  Pads S to a block multiple."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    pq = -(-sq // block_q) * block_q - sq
    pk = -(-sk // block_k) * block_k - sk
    if pq or pk:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        # padded keys masked out via causal structure only when causal;
        # for bidirectional we mask by pushing scores to -inf through a
        # sentinel: simplest correct move — require causal when padding k.
        assert causal or pk == 0, "pad-free Sk required for bidirectional"
    out = flash_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return out[:, :, :sq]
