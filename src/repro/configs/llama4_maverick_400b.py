"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, vocab=202048, MoE 128 experts top-1 + shared expert; iRoPE-style
3:1 chunked:global attention (chunk window 8192).
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = ArchSpec(
    arch_id="llama4-maverick-400b-a17b",
    family="lm",
    model=LMConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        # assigned d_ff=8192 is the per-expert dim; interleaved dense
        # layers use 16384 (published Maverick: interleave_moe_layer_step=2)
        # -> 401B total / 17.2B active, matching the model name.
        d_ff=16384,
        vocab=202_048,
        rope_theta=500_000.0,
        moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192,
                      n_shared_experts=1, n_groups=32),
        moe_interleave=2,
        local_global=(3, 1),
        window=8192,
        tie_embeddings=False,
    ),
    # chunked-attention layers are sub-quadratic; long_500k runs.
    shapes=lm_shapes(long_skip=None, train_accum=8),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="llama4-maverick-smoke",
        family="lm",
        model=LMConfig(
            name="llama4-maverick-smoke",
            n_layers=4,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            moe=MoEConfig(n_experts=4, top_k=1, d_ff=128,
                          n_shared_experts=1),
            local_global=(3, 1),
            window=8,
            tie_embeddings=False,
            remat=False,
        ),
        shapes=lm_shapes(long_skip=None),
    )
