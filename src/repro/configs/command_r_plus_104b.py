"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel attn+FFN block.
[hf:CohereForAI/c4ai-command-r-plus family; unverified]"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = ArchSpec(
    arch_id="command-r-plus-104b",
    family="lm",
    model=LMConfig(
        name="command-r-plus-104b",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        vocab=256_000,
        rope_theta=75_000_000.0,
        parallel_block=True,
        tie_embeddings=True,
    ),
    shapes=lm_shapes(
        train_accum=16,
        long_skip="pure full-attention stack; long_500k reserved for "
        "sub-quadratic archs (DESIGN.md §Arch-applicability)"
    ),
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="command-r-plus-104b-smoke",
        family="lm",
        model=LMConfig(
            name="command-r-plus-104b-smoke",
            n_layers=2,
            d_model=96,
            n_heads=6,
            n_kv_heads=2,
            head_dim=16,
            d_ff=256,
            vocab=512,
            parallel_block=True,
            remat=False,
        ),
        shapes=lm_shapes(long_skip="smoke"),
    )
