"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global interleave, sliding window 1024.
[hf:google/gemma-3-12b-pt family; unverified]"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = ArchSpec(
    arch_id="gemma3-12b",
    family="lm",
    model=LMConfig(
        name="gemma3-12b",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab=262_144,
        rope_theta=1_000_000.0,
        local_global=(5, 1),
        window=1024,
        tie_embeddings=True,
    ),
    # local layers are sub-quadratic (sliding window); long_500k runs.
    shapes=lm_shapes(long_skip=None, train_accum=8),
    source="hf:google/gemma-3-1b-pt; unverified",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="gemma3-12b-smoke",
        family="lm",
        model=LMConfig(
            name="gemma3-12b-smoke",
            n_layers=6,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            local_global=(5, 1),
            window=8,
            remat=False,
        ),
        shapes=lm_shapes(long_skip=None),
    )
