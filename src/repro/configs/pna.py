"""pna [gnn]: n_layers=4 d_hidden=75 aggregators=mean-max-min-std
scalers=identity-amplification-attenuation. [arXiv:2004.05718; paper]"""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn.pna import PNAConfig

CONFIG = ArchSpec(
    arch_id="pna",
    family="gnn",
    model=PNAConfig(
        name="pna",
        n_layers=4,
        d_hidden=75,
        n_classes=8,
        d_in=16,
    ),
    shapes=GNN_SHAPES,
    source="arXiv:2004.05718; paper",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="pna-smoke",
        family="gnn",
        model=PNAConfig(
            name="pna-smoke", n_layers=2, d_hidden=8, n_classes=4, d_in=8,
        ),
        shapes=GNN_SHAPES,
    )
