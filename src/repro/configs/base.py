"""Config schema: ArchSpec = model config + its assigned shape set.

Every assigned architecture gets one module defining ``CONFIG`` (exact
published hyperparameters) and ``smoke()`` (a reduced same-family config
for CPU tests).  The launcher resolves ``--arch <id> --shape <name>`` to a
(model, ShapeSpec) pair; the dry-run walks REGISTRY x shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell of the (arch x shape) grid."""

    name: str
    kind: str  # train | prefill | decode | graph_train | recsys_train |
               # recsys_serve | recsys_retrieval
    dims: dict[str, int]
    skip: str | None = None  # reason if this cell is skipped (documented)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # lm | gnn | recsys
    model: Any                        # LMConfig | GATConfig | ...
    shapes: dict[str, ShapeSpec]
    source: str = ""                  # provenance tag from the assignment
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]


# ---- assigned LM shape set (identical for the 5 LM archs) ----------------

def lm_shapes(*, long_skip: str | None,
              train_accum: int = 8) -> dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec(
            "train_4k", "train",
            # accum_steps = gradient accumulation (microbatch = global /
            # accum): the production memory-fit knob, chosen per arch so
            # the rematted step stays under one v5e HBM (16 GB).
            {"seq_len": 4096, "global_batch": 256,
             "accum_steps": train_accum},
        ),
        "prefill_32k": ShapeSpec(
            "prefill_32k", "prefill",
            {"seq_len": 32768, "global_batch": 32},
        ),
        "decode_32k": ShapeSpec(
            "decode_32k", "decode",
            {"seq_len": 32768, "global_batch": 128},
        ),
        "long_500k": ShapeSpec(
            "long_500k", "decode",
            {"seq_len": 524288, "global_batch": 1},
            skip=long_skip,
        ),
    }


GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "graph_train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
         "n_classes": 7},
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "graph_train",
        # reddit-scale host graph; the device step sees the sampled block
        {"n_nodes": 232_965, "n_edges": 114_615_892, "batch_nodes": 1024,
         "fanout0": 15, "fanout1": 10, "d_feat": 602, "n_classes": 41},
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "graph_train",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
         "n_classes": 47},
    ),
    "molecule": ShapeSpec(
        "molecule", "graph_train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16,
         "n_classes": 8},
    ),
}


RECSYS_SHAPES = {
    "train_batch": ShapeSpec(
        "train_batch", "recsys_train", {"batch": 65_536}
    ),
    "serve_p99": ShapeSpec(
        "serve_p99", "recsys_serve", {"batch": 512}
    ),
    "serve_bulk": ShapeSpec(
        "serve_bulk", "recsys_serve", {"batch": 262_144}
    ),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "recsys_retrieval",
        {"batch": 1, "n_candidates": 1_000_000},
    ),
}
