"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = ArchSpec(
    arch_id="llama3.2-1b",
    family="lm",
    model=LMConfig(
        name="llama3.2-1b",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab=128_256,
        rope_theta=500_000.0,
        tie_embeddings=True,
    ),
    shapes=lm_shapes(
        train_accum=2,
        long_skip="pure full-attention stack; long_500k reserved for "
        "sub-quadratic archs (DESIGN.md §Arch-applicability)"
    ),
    source="hf:meta-llama/Llama-3.2-1B; unverified",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="llama3.2-1b-smoke",
        family="lm",
        model=LMConfig(
            name="llama3.2-1b-smoke",
            n_layers=2,
            d_model=64,
            n_heads=8,
            n_kv_heads=2,
            head_dim=8,
            d_ff=256,
            vocab=512,
            remat=False,
        ),
        shapes=lm_shapes(long_skip="smoke"),
    )
