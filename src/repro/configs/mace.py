"""mace [gnn]: n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8
E(3)-ACE higher-order equivariant message passing. [arXiv:2206.07697; paper]
"""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn.equivariant import EquivariantConfig

CONFIG = ArchSpec(
    arch_id="mace",
    family="gnn",
    model=EquivariantConfig(
        name="mace",
        kind="mace",
        n_layers=2,
        d_hidden=128,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
        correlation_order=3,
    ),
    shapes=GNN_SHAPES,
    source="arXiv:2206.07697; paper",
    notes="many-body (cardinality-k) interactions = the hypergraph-native "
          "arch of the pool; see DESIGN.md §7",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="mace-smoke",
        family="gnn",
        model=EquivariantConfig(
            name="mace-smoke", kind="mace", n_layers=2, d_hidden=8,
            l_max=2, n_rbf=4, correlation_order=3, n_species=4,
        ),
        shapes=GNN_SHAPES,
    )
