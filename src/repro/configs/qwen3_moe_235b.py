"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = ArchSpec(
    arch_id="qwen3-moe-235b-a22b",
    family="lm",
    model=LMConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab=151_936,
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=1536,
                      n_groups=32),
        tie_embeddings=False,
    ),
    shapes=lm_shapes(
        train_accum=8,
        long_skip="pure full-attention stack; long_500k reserved for "
        "sub-quadratic archs (DESIGN.md §Arch-applicability)"
    ),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen3-moe-235b-a22b-smoke",
        family="lm",
        model=LMConfig(
            name="qwen3-moe-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=64,
            vocab=512,
            moe=MoEConfig(n_experts=8, top_k=2, d_ff=64),
            tie_embeddings=False,
            remat=False,
        ),
        shapes=lm_shapes(long_skip="smoke"),
    )
