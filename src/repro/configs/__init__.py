"""Architecture registry: ``--arch <id>`` resolution for the launcher.

10 assigned architectures + the paper's own hypergraph workload configs.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ArchSpec, ShapeSpec

_MODULES = {
    "gemma3-12b": "repro.configs.gemma3_12b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "mace": "repro.configs.mace",
    "nequip": "repro.configs.nequip",
    "gat-cora": "repro.configs.gat_cora",
    "pna": "repro.configs.pna",
    "bert4rec": "repro.configs.bert4rec",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ArchSpec:
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.smoke() if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ArchSpec]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}


__all__ = ["ArchSpec", "ShapeSpec", "ARCH_IDS", "get_config", "all_configs"]
