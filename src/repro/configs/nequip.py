"""nequip [gnn]: n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5
O(3)-equivariant interatomic potential. [arXiv:2101.03164; paper]"""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn.equivariant import EquivariantConfig

CONFIG = ArchSpec(
    arch_id="nequip",
    family="gnn",
    model=EquivariantConfig(
        name="nequip",
        kind="nequip",
        n_layers=5,
        d_hidden=32,
        l_max=2,
        n_rbf=8,
        cutoff=5.0,
    ),
    shapes=GNN_SHAPES,
    source="arXiv:2101.03164; paper",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="nequip-smoke",
        family="gnn",
        model=EquivariantConfig(
            name="nequip-smoke", kind="nequip", n_layers=2, d_hidden=8,
            l_max=1, n_rbf=4, n_species=4,
        ),
        shapes=GNN_SHAPES,
    )
