"""gat-cora [gnn]: n_layers=2 d_hidden=8 n_heads=8 attention aggregator.
[arXiv:1710.10903; paper]"""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn.gat import GATConfig

CONFIG = ArchSpec(
    arch_id="gat-cora",
    family="gnn",
    model=GATConfig(
        name="gat-cora",
        n_layers=2,
        d_hidden=8,
        n_heads=8,
        n_classes=7,
        d_in=1433,
    ),
    shapes=GNN_SHAPES,
    source="arXiv:1710.10903; paper",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="gat-cora-smoke",
        family="gnn",
        model=GATConfig(
            name="gat-smoke", n_layers=2, d_hidden=4, n_heads=2,
            n_classes=4, d_in=8,
        ),
        shapes=GNN_SHAPES,
    )
