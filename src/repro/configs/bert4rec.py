"""bert4rec [recsys]: embed_dim=64 n_blocks=2 n_heads=2 seq_len=200,
bidirectional sequence interaction over a 1M-item catalog.
[arXiv:1904.06690; paper]"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys.bert4rec import BERT4RecConfig

CONFIG = ArchSpec(
    arch_id="bert4rec",
    family="recsys",
    model=BERT4RecConfig(
        name="bert4rec",
        n_items=1_000_000,
        embed_dim=64,
        n_blocks=2,
        n_heads=2,
        max_seq=200,
    ),
    shapes=RECSYS_SHAPES,
    source="arXiv:1904.06690; paper",
    notes="encoder-only: no autoregressive decode shapes assigned (all 4 "
          "cells run)",
)


def smoke() -> ArchSpec:
    return ArchSpec(
        arch_id="bert4rec-smoke",
        family="recsys",
        model=BERT4RecConfig(
            name="bert4rec-smoke", n_items=1000, embed_dim=16,
            n_blocks=2, n_heads=2, max_seq=16,
        ),
        shapes=RECSYS_SHAPES,
    )
